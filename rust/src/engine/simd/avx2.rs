//! AVX2 inner kernels (x86_64). See the module docs in [`super`] for the
//! tier contract; the short version:
//!
//! * integer kernels read ROW-MAJOR weights (no `[k][4]` interleave — each
//!   output channel's payload is one contiguous byte stream) and widen
//!   u8→i16 / i8→i16 before `_mm256_madd_epi16`, which is exact: a pair
//!   product is at most `255·128`, so the i16-pair dot sum fits i32 with
//!   no saturation (the `maddubs` shortcut saturates at i16 and is
//!   deliberately NOT used). i32 accumulation is order-independent, so
//!   outputs are bit-identical to the scalar kernels.
//! * float kernels read the same `[k][4]`-interleaved panels as the scalar
//!   tier and vectorize ACROSS the panel: the four accumulator lanes are
//!   the scalar kernel's `a0..a3`, updated with separate mul and add
//!   intrinsics (never contracted to FMA), so each lane replays the scalar
//!   per-output accumulation order bit-for-bit.
//!
//! Every function carries `#[target_feature(enable = "avx2")]`; callers
//! guarantee AVX2 support (the tier is only resolved on machines where
//! `is_x86_feature_detected!("avx2")` holds). Only the pointer-based
//! loads/stores are `unsafe` — value intrinsics are safe inside the
//! feature context.

use std::arch::x86_64::*;

use crate::engine::ops::{apply_act, nib_hi, nib_lo, Act};
use crate::tensor::quantized::packed_row_bytes;

/// Horizontal sum of the eight i32 lanes.
#[target_feature(enable = "avx2")]
fn hsum_epi32(v: __m256i) -> i32 {
    let mut lanes = [0i32; 8];
    // SAFETY: `lanes` is 32 writable bytes; the unaligned store has no
    // alignment requirement.
    unsafe { _mm256_storeu_si256(lanes.as_mut_ptr().cast(), v) };
    lanes.iter().sum()
}

/// Unpack 8 nibble-packed int4 bytes (low half of `v`) into 16
/// sign-extended i8 values in k order: byte `b` carries `k = 2b` in its
/// low nibble and `k = 2b + 1` in its high nibble.
#[target_feature(enable = "avx2")]
fn unpack_nibbles16(v: __m128i) -> __m128i {
    let low = _mm_set1_epi8(0x0f);
    let eight = _mm_set1_epi8(8);
    let lo = _mm_and_si128(v, low);
    // per-byte high nibble via the 16-bit shifter; the cross-byte bleed is
    // masked off
    let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), low);
    // 4-bit sign extension: (n ^ 8) - 8 maps 0..=15 to -8..=7
    let lo = _mm_sub_epi8(_mm_xor_si128(lo, eight), eight);
    let hi = _mm_sub_epi8(_mm_xor_si128(hi, eight), eight);
    _mm_unpacklo_epi8(lo, hi)
}

/// Row-range AVX2 kernel over row-major i8 weights: bit-identical to the
/// scalar kernels (shared requantization epilogue, order-independent i32
/// accumulation), 16 k-steps per vector iteration, 4-way output-channel
/// register blocking sharing one widened activation vector.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(crate) fn gemm_i8_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let kb = cols - cols % 16;
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let w0 = &wq[o * cols..(o + 1) * cols];
            let w1 = &wq[(o + 1) * cols..(o + 2) * cols];
            let w2 = &wq[(o + 2) * cols..(o + 3) * cols];
            let w3 = &wq[(o + 3) * cols..(o + 4) * cols];
            let mut v0 = _mm256_setzero_si256();
            let mut v1 = _mm256_setzero_si256();
            let mut v2 = _mm256_setzero_si256();
            let mut v3 = _mm256_setzero_si256();
            let mut k = 0;
            while k + 16 <= cols {
                // SAFETY: k + 16 <= cols and each of the five row slices
                // holds `cols` bytes, so every 16-byte unaligned load is in
                // bounds.
                let (xv, wv0, wv1, wv2, wv3) = unsafe {
                    (
                        _mm_loadu_si128(xrow.as_ptr().add(k).cast()),
                        _mm_loadu_si128(w0.as_ptr().add(k).cast()),
                        _mm_loadu_si128(w1.as_ptr().add(k).cast()),
                        _mm_loadu_si128(w2.as_ptr().add(k).cast()),
                        _mm_loadu_si128(w3.as_ptr().add(k).cast()),
                    )
                };
                let x16 = _mm256_cvtepu8_epi16(xv);
                v0 = _mm256_add_epi32(v0, _mm256_madd_epi16(x16, _mm256_cvtepi8_epi16(wv0)));
                v1 = _mm256_add_epi32(v1, _mm256_madd_epi16(x16, _mm256_cvtepi8_epi16(wv1)));
                v2 = _mm256_add_epi32(v2, _mm256_madd_epi16(x16, _mm256_cvtepi8_epi16(wv2)));
                v3 = _mm256_add_epi32(v3, _mm256_madd_epi16(x16, _mm256_cvtepi8_epi16(wv3)));
                k += 16;
            }
            let mut a0 = hsum_epi32(v0);
            let mut a1 = hsum_epi32(v1);
            let mut a2 = hsum_epi32(v2);
            let mut a3 = hsum_epi32(v3);
            for i in kb..cols {
                let x = xrow[i] as i32;
                a0 += x * w0[i] as i32;
                a1 += x * w1[i] as i32;
                a2 += x * w2[i] as i32;
                a3 += x * w3[i] as i32;
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wq[o * cols..(o + 1) * cols];
            let mut v = _mm256_setzero_si256();
            let mut k = 0;
            while k + 16 <= cols {
                // SAFETY: k + 16 <= cols; xrow and wrow both hold `cols`
                // bytes, so both 16-byte unaligned loads are in bounds.
                let (xv, wv) = unsafe {
                    (
                        _mm_loadu_si128(xrow.as_ptr().add(k).cast()),
                        _mm_loadu_si128(wrow.as_ptr().add(k).cast()),
                    )
                };
                let prod = _mm256_madd_epi16(_mm256_cvtepu8_epi16(xv), _mm256_cvtepi8_epi16(wv));
                v = _mm256_add_epi32(v, prod);
                k += 16;
            }
            let mut acc = hsum_epi32(v);
            for i in kb..cols {
                acc += xrow[i] as i32 * wrow[i] as i32;
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

/// Row-range AVX2 kernel over row-major nibble-packed i4 weights: 8 packed
/// bytes (16 k-steps) are unpacked per vector iteration via
/// [`unpack_nibbles16`], then fed through the same widened `madd` dot
/// product as the i8 kernel. The sub-16 byte tail and the odd-column low
/// nibble run the scalar helpers. Bit-identical to `gemm_i4_rows` /
/// `gemm_i4_panel_rows` in `engine::ops`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(crate) fn gemm_i4_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let bpr = packed_row_bytes(cols);
    let pairs = cols / 2;
    let vb = pairs - pairs % 8;
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let w0 = &wq[o * bpr..(o + 1) * bpr];
            let w1 = &wq[(o + 1) * bpr..(o + 2) * bpr];
            let w2 = &wq[(o + 2) * bpr..(o + 3) * bpr];
            let w3 = &wq[(o + 3) * bpr..(o + 4) * bpr];
            let mut v0 = _mm256_setzero_si256();
            let mut v1 = _mm256_setzero_si256();
            let mut v2 = _mm256_setzero_si256();
            let mut v3 = _mm256_setzero_si256();
            let mut b = 0;
            while b + 8 <= vb {
                // SAFETY: b + 8 <= vb <= pairs <= bpr, so each 8-byte weight
                // load is in bounds; 2b + 16 <= 2·pairs <= cols keeps the
                // 16-byte activation load in bounds too.
                let (xv, wv0, wv1, wv2, wv3) = unsafe {
                    (
                        _mm_loadu_si128(xrow.as_ptr().add(2 * b).cast()),
                        _mm_loadl_epi64(w0.as_ptr().add(b).cast()),
                        _mm_loadl_epi64(w1.as_ptr().add(b).cast()),
                        _mm_loadl_epi64(w2.as_ptr().add(b).cast()),
                        _mm_loadl_epi64(w3.as_ptr().add(b).cast()),
                    )
                };
                let x16 = _mm256_cvtepu8_epi16(xv);
                let u0 = _mm256_cvtepi8_epi16(unpack_nibbles16(wv0));
                let u1 = _mm256_cvtepi8_epi16(unpack_nibbles16(wv1));
                let u2 = _mm256_cvtepi8_epi16(unpack_nibbles16(wv2));
                let u3 = _mm256_cvtepi8_epi16(unpack_nibbles16(wv3));
                v0 = _mm256_add_epi32(v0, _mm256_madd_epi16(x16, u0));
                v1 = _mm256_add_epi32(v1, _mm256_madd_epi16(x16, u1));
                v2 = _mm256_add_epi32(v2, _mm256_madd_epi16(x16, u2));
                v3 = _mm256_add_epi32(v3, _mm256_madd_epi16(x16, u3));
                b += 8;
            }
            let mut a0 = hsum_epi32(v0);
            let mut a1 = hsum_epi32(v1);
            let mut a2 = hsum_epi32(v2);
            let mut a3 = hsum_epi32(v3);
            for kb in vb..pairs {
                let x0 = xrow[2 * kb] as i32;
                let x1 = xrow[2 * kb + 1] as i32;
                a0 += x0 * nib_lo(w0[kb]) + x1 * nib_hi(w0[kb]);
                a1 += x0 * nib_lo(w1[kb]) + x1 * nib_hi(w1[kb]);
                a2 += x0 * nib_lo(w2[kb]) + x1 * nib_hi(w2[kb]);
                a3 += x0 * nib_lo(w3[kb]) + x1 * nib_hi(w3[kb]);
            }
            if cols % 2 == 1 {
                let x0 = xrow[cols - 1] as i32;
                a0 += x0 * nib_lo(w0[bpr - 1]);
                a1 += x0 * nib_lo(w1[bpr - 1]);
                a2 += x0 * nib_lo(w2[bpr - 1]);
                a3 += x0 * nib_lo(w3[bpr - 1]);
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wq[o * bpr..(o + 1) * bpr];
            let mut v = _mm256_setzero_si256();
            let mut b = 0;
            while b + 8 <= vb {
                // SAFETY: b + 8 <= vb <= pairs <= bpr bounds the 8-byte
                // weight load; 2b + 16 <= cols bounds the activation load.
                let (xv, wv) = unsafe {
                    (
                        _mm_loadu_si128(xrow.as_ptr().add(2 * b).cast()),
                        _mm_loadl_epi64(wrow.as_ptr().add(b).cast()),
                    )
                };
                let u = _mm256_cvtepi8_epi16(unpack_nibbles16(wv));
                v = _mm256_add_epi32(v, _mm256_madd_epi16(_mm256_cvtepu8_epi16(xv), u));
                b += 8;
            }
            let mut acc = hsum_epi32(v);
            for kb in vb..pairs {
                acc += xrow[2 * kb] as i32 * nib_lo(wrow[kb])
                    + xrow[2 * kb + 1] as i32 * nib_hi(wrow[kb]);
            }
            if cols % 2 == 1 {
                acc += xrow[cols - 1] as i32 * nib_lo(wrow[bpr - 1]);
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

/// 4-lane twin of the scalar `gemm_f32_panel_rows` (the 64-wide k-blocked
/// convolution form). Each accumulator LANE replays the scalar kernel's
/// per-output operation sequence — separate mul and add per k step, block
/// partials folded in the same order — so outputs are bit-identical.
/// Remainder rows (< 4 channels) run the scalar loop unchanged.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(crate) fn gemm_f32_panel_rows(
    x: &[f32],
    rows: usize,
    cols: usize,
    wp: &[f32],
    cout_g: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    const BK: usize = 64;
    for r in 0..rows {
        let xrow = &x[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let pan = &wp[o * cols..(o + 4) * cols];
            let mut a = _mm_setzero_ps();
            let mut k = 0;
            while k + BK <= cols {
                let mut s = _mm_setzero_ps();
                for i in k..k + BK {
                    // SAFETY: i < cols, so the 4-wide load at i*4 ends at
                    // i*4 + 4 <= 4*cols == pan.len().
                    let wv = unsafe { _mm_loadu_ps(pan.as_ptr().add(i * 4)) };
                    s = _mm_add_ps(s, _mm_mul_ps(_mm_set1_ps(xrow[i]), wv));
                }
                a = _mm_add_ps(a, s);
                k += BK;
            }
            for i in k..cols {
                // SAFETY: i < cols, as above.
                let wv = unsafe { _mm_loadu_ps(pan.as_ptr().add(i * 4)) };
                a = _mm_add_ps(a, _mm_mul_ps(_mm_set1_ps(xrow[i]), wv));
            }
            let mut lanes = [0.0f32; 4];
            // SAFETY: `lanes` is 16 writable bytes; unaligned store.
            unsafe { _mm_storeu_ps(lanes.as_mut_ptr(), a) };
            for (j, acc) in lanes.into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[o0 + oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < cout_g {
            // remainder rows are stored row-major at offset o*cols; this is
            // the scalar remainder loop verbatim
            let wrow = &wp[o * cols..(o + 1) * cols];
            let mut acc = 0.0f32;
            let mut k = 0;
            while k + BK <= cols {
                let mut s = 0.0f32;
                for i in k..k + BK {
                    s += xrow[i] * wrow[i];
                }
                acc += s;
                k += BK;
            }
            for i in k..cols {
                acc += xrow[i] * wrow[i];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o0 + o] = apply_act(acc, act);
            o += 1;
        }
    }
}

/// 4-lane twin of the scalar `linear_f32_panel_rows` (plain unblocked
/// accumulation — the linear / attention-projection form). Same lane
/// contract as [`gemm_f32_panel_rows`]: bit-identical outputs.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(crate) fn linear_f32_panel_rows(
    x: &[f32],
    rows: usize,
    din: usize,
    wp: &[f32],
    dout: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
) {
    for r in 0..rows {
        let xrow = &x[r * din..(r + 1) * din];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut o = 0;
        while o + 4 <= dout {
            let pan = &wp[o * din..(o + 4) * din];
            let mut a = _mm_setzero_ps();
            for k in 0..din {
                // SAFETY: k < din, so the 4-wide load at k*4 ends at
                // k*4 + 4 <= 4*din == pan.len().
                let wv = unsafe { _mm_loadu_ps(pan.as_ptr().add(k * 4)) };
                a = _mm_add_ps(a, _mm_mul_ps(_mm_set1_ps(xrow[k]), wv));
            }
            let mut lanes = [0.0f32; 4];
            // SAFETY: `lanes` is 16 writable bytes; unaligned store.
            unsafe { _mm_storeu_ps(lanes.as_mut_ptr(), a) };
            for (j, acc) in lanes.into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < dout {
            let wrow = &wp[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for k in 0..din {
                acc += xrow[k] * wrow[k];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o] = apply_act(acc, act);
            o += 1;
        }
    }
}
