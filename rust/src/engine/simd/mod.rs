//! Kernel tiers: plan-time runtime CPU-feature detection and the SIMD
//! inner kernels behind the planned GEMMs.
//!
//! The engine ships three tiers of inner kernels:
//!
//! * [`KernelTier::Scalar`] — the portable reference kernels in
//!   `engine::ops`. Always available, always correct; every other tier is
//!   asserted bit-identical against it.
//! * [`KernelTier::Avx2`] — 256-bit x86_64 kernels ([`avx2`]): widening
//!   i8×i8→i32 dot products (`_mm256_madd_epi16` after an exact u8→i16 /
//!   i8→i16 widen — never the saturating `maddubs` form) for the INT8 and
//!   nibble-packed INT4 GEMMs, and 4-lane float panels for the f32 path.
//! * [`KernelTier::Neon`] — 128-bit aarch64 equivalents ([`neon`]) built
//!   on `vmlal_s16` widening multiply-accumulates.
//!
//! The tier is resolved ONCE per deployment, in `ExecPlan::compile`
//! ([`KernelTier::resolve`]), and recorded on the plan and on every
//! prepacked weight panel — dispatch afterwards is a branch on a stored
//! enum, never a per-call feature probe.
//!
//! ## Bit-exactness contract
//!
//! Per-output accumulation must be reproducible across tiers (the
//! plan-vs-interpreter contract of `tests/plan_exactness.rs`):
//!
//! * **integer GEMMs** — i32 addition is associative and commutative, so
//!   the 8-lane (AVX2) / 4-lane (NEON) partial accumulators sum to exactly
//!   the scalar kernel's accumulator for ANY reassociation; the
//!   requantization epilogue is shared verbatim. The static accumulator
//!   interval of `qir::analysis::acc_bounds` contains every partial sum of
//!   any subset of terms, so the vectorized order needs no new headroom.
//! * **float GEMMs** — f32 addition is NOT associative, so the float
//!   kernels vectorize across the 4-output-channel panel dimension
//!   instead: the four accumulator lanes ARE the scalar kernel's four
//!   accumulators, each updated with the same mul-then-add per k step
//!   (explicit intrinsics are never contracted into FMA), preserving the
//!   scalar accumulation order bit-for-bit per output.
//!
//! Forcing the fallback tier: set `PALLAS_FORCE_SCALAR=1` (any non-empty
//! value other than `0`) — it overrides both auto-detection and an
//! explicit `ExecConfig::kernel_tier`, which is what the CI kernel-matrix
//! job uses to run the whole suite on the scalar tier.

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

/// Environment variable forcing [`KernelTier::Scalar`] everywhere,
/// regardless of detection or explicit configuration.
pub const FORCE_SCALAR_ENV: &str = "PALLAS_FORCE_SCALAR";

/// Inner-kernel instruction tier of a compiled execution plan. Resolved
/// once at plan time; see the module docs for the dispatch and
/// bit-exactness rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelTier {
    /// Portable scalar kernels — the always-correct fallback tier.
    Scalar,
    /// 256-bit AVX2 integer / 4-lane float kernels (x86_64 with AVX2).
    Avx2,
    /// 128-bit NEON kernels (aarch64, where NEON is architecturally
    /// baseline).
    Neon,
}

impl KernelTier {
    /// The tier a fresh plan would use on this machine right now:
    /// [`FORCE_SCALAR_ENV`] wins, then the best tier the running CPU
    /// supports.
    pub fn detect() -> KernelTier {
        KernelTier::resolve(None)
    }

    /// Resolve the tier for a plan: the [`FORCE_SCALAR_ENV`] kill-switch
    /// overrides everything; otherwise an explicit request is honored when
    /// this machine can run it (and degraded to `Scalar` when it cannot —
    /// a plan must never dispatch an instruction set the host lacks);
    /// otherwise the best available tier is detected.
    pub fn resolve(requested: Option<KernelTier>) -> KernelTier {
        if force_scalar() {
            return KernelTier::Scalar;
        }
        let tier = requested.unwrap_or_else(KernelTier::native);
        if tier.available() {
            tier
        } else {
            KernelTier::Scalar
        }
    }

    /// Best tier the running CPU supports (ignoring overrides).
    fn native() -> KernelTier {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                KernelTier::Avx2
            } else {
                KernelTier::Scalar
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            KernelTier::Neon
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            KernelTier::Scalar
        }
    }

    /// True when this machine can execute the tier's kernels.
    pub fn available(self) -> bool {
        match self {
            KernelTier::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelTier::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => true,
            _ => false,
        }
    }

    /// Stable lowercase name (bench JSON, logs, reports).
    pub fn label(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Neon => "neon",
        }
    }

    /// True when the tier reads integer weight panels in the scalar
    /// kernels' `[k][4]`-interleaved layout. SIMD tiers keep the payload
    /// row-major instead: their dot-product loops read each output
    /// channel's row as one contiguous byte stream. (`ops::PackedQW::pack_for`
    /// packs accordingly; float panels are `[k][4]`-interleaved on every
    /// tier because the float kernels vectorize across the panel lanes.)
    pub(crate) fn interleaved_int_panels(self) -> bool {
        matches!(self, KernelTier::Scalar)
    }
}

/// True when [`FORCE_SCALAR_ENV`] is set to a non-empty value other than
/// `0`.
fn force_scalar() -> bool {
    std::env::var_os(FORCE_SCALAR_ENV).is_some_and(|v| !v.is_empty() && v != "0")
}

#[cfg(test)]
mod tests {
    use super::KernelTier;

    #[test]
    fn scalar_is_always_available_and_resolution_is_sane() {
        assert!(KernelTier::Scalar.available());
        let auto = KernelTier::detect();
        assert!(auto.available(), "detected tier must be runnable: {auto:?}");
        // an explicit available request is honored (unless the env
        // kill-switch is set, in which case everything is Scalar)
        let forced = KernelTier::resolve(Some(KernelTier::Scalar));
        assert_eq!(forced, KernelTier::Scalar);
        assert_eq!(KernelTier::resolve(Some(auto)), KernelTier::resolve(Some(auto)));
    }

    #[test]
    fn foreign_tier_requests_degrade_to_scalar() {
        // a tier this target cannot execute must never be resolved
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(KernelTier::resolve(Some(KernelTier::Neon)), KernelTier::Scalar);
        #[cfg(not(target_arch = "x86_64"))]
        assert_eq!(KernelTier::resolve(Some(KernelTier::Avx2)), KernelTier::Scalar);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(KernelTier::Scalar.label(), "scalar");
        assert_eq!(KernelTier::Avx2.label(), "avx2");
        assert_eq!(KernelTier::Neon.label(), "neon");
    }
}
