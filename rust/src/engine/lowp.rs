//! Low-precision float simulation: bf16 / f16 round-trips.
//!
//! Backends like Hardware B run activations in BF16 (Table 4 "W8/ABF16
//! hybrid"); Jetson/TensorRT paths use FP16. We simulate by rounding f32
//! payloads through the narrow format at op boundaries — the same numerics a
//! real mixed-precision pipeline exhibits at tensor granularity.

/// Worst-case relative rounding error of one bf16 round-trip (8 mantissa
/// bits incl. the implicit one → half-ulp ≤ 2⁻⁸·|x|). The static analyzer
/// (`engine::verify`) widens propagated intervals by this per op under
/// [`ActMode::Bf16`]; `tests` below assert the bound empirically.
///
/// [`ActMode::Bf16`]: crate::engine::ActMode::Bf16
pub const BF16_REL_STEP: f64 = 1.0 / 256.0;
/// Worst-case relative rounding error of one f16 round-trip (11 mantissa
/// bits → half-ulp ≤ 2⁻¹⁰·|x| with margin).
pub const F16_REL_STEP: f64 = 1.0 / 1024.0;
/// Largest finite IEEE binary16 value: [`f32_to_f16`] maps anything that
/// rounds past this to ±∞, which is what the analyzer's overflow threshold
/// models.
pub const F16_MAX_FINITE: f64 = 65504.0;

/// Round f32 -> bf16 -> f32 (round-to-nearest-even on the dropped mantissa).
#[inline]
pub fn bf16(x: f32) -> f32 {
    let bits = x.to_bits();
    // round to nearest even at bit 16
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb);
    f32::from_bits(rounded & 0xffff_0000)
}

/// Round f32 -> IEEE f16 -> f32.
#[inline]
pub fn f16(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

/// f32 -> IEEE binary16 bits (round-to-nearest-even, with overflow->inf,
/// subnormal handling).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal f16
        let half_exp = ((unbiased + 15) as u16) << 10;
        let shifted = mant >> 13;
        let round_bits = mant & 0x1fff;
        let mut h = sign | half_exp | shifted as u16;
        if round_bits > 0x1000 || (round_bits == 0x1000 && (shifted & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent: correct behaviour
        }
        h
    } else if unbiased >= -24 {
        // subnormal
        let full_mant = mant | 0x0080_0000;
        let shift = (-unbiased - 14 + 13) as u32;
        let shifted = full_mant >> shift;
        let rem = full_mant & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = sign | shifted as u16;
        if rem > halfway || (rem == halfway && (shifted & 1) == 1) {
            h = h.wrapping_add(1);
        }
        h
    } else {
        sign // underflow -> signed zero
    }
}

/// IEEE binary16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: value = mant * 2^-24; normalize so bit 10 is set
            // after k shifts, biased f32 exponent = 127 - 14 - k
            let mut m = mant;
            let mut k = 0u32;
            while m & 0x0400 == 0 {
                m <<= 1;
                k += 1;
            }
            m &= 0x03ff;
            sign | ((127 - 14 - k) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Apply a narrowing round-trip to a whole slice in place.
pub fn narrow_slice(data: &mut [f32], f: impl Fn(f32) -> f32) {
    for v in data.iter_mut() {
        *v = f(*v);
    }
}

/// In-place bf16 round-trip over a slice (monomorphized hot path for the
/// planned executor — avoids the per-call closure indirection).
pub fn bf16_slice(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = bf16(*v);
    }
}

/// In-place f16 round-trip over a slice.
pub fn f16_slice(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = f16(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_preserves_coarse_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 1024.0, -3.140625] {
            assert_eq!(bf16(v), v, "{v} should be bf16-exact");
        }
    }

    #[test]
    fn bf16_rounds_fine_mantissa() {
        let v = 1.0 + f32::EPSILON;
        assert_eq!(bf16(v), 1.0);
        // relative error bounded by 2^-8
        for i in 1..100 {
            let x = 0.731 * i as f32;
            assert!((bf16(x) - x).abs() <= x.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE);
        }
    }

    #[test]
    fn f16_roundtrip_exact_values() {
        for &v in &[0.0f32, 1.0, -2.0, 0.25, 65504.0, -65504.0] {
            assert_eq!(f16(v), v, "{v} should be f16-exact");
        }
    }

    #[test]
    fn f16_overflow_and_subnormals() {
        assert!(f16(70000.0).is_infinite());
        let tiny = 6e-8f32; // representable as f16 subnormal
        let r = f16(tiny);
        assert!(r > 0.0 && (r - tiny).abs() / tiny < 0.5);
        assert_eq!(f16(1e-12), 0.0);
    }

    #[test]
    fn f16_relative_error_bound() {
        for i in 1..200 {
            let x = 0.173 * i as f32;
            assert!((f16(x) - x).abs() <= x.abs() * (1.0 / 1024.0) + 1e-7);
        }
    }
}
