//! `quant-trim` CLI: fleet inspection, config dumps, training, deployment.
//! The heavy experiment drivers live in examples/ (see README); this binary
//! covers the quick operational commands.

use anyhow::{bail, Result};

use quant_trim::backends::{all_backends, backend_by_name};
use quant_trim::coordinator::Curriculum;

fn usage() -> ! {
    eprintln!(
        "quant-trim — hardware-neutral low-bit deployment (Quant-Trim reproduction)

USAGE:
  quant-trim devices              print the simulated device fleet (paper Tables 4-6)
  quant-trim config --show        print curriculum defaults (paper Tables 7-8)
  quant-trim lambda <e_w> <e_f> <H> <epochs>
                                  print the blend schedule
  quant-trim backend <name>       details for one backend

The experiment drivers are cargo examples:
  cargo run --release --example quickstart
  cargo run --release --example train_cifar -- --model resnet18 --epochs 20
  cargo run --release --example deploy_matrix
  cargo run --release --example edge_benchmark
  cargo run --release --example ablation
  cargo run --release --example nanosam_distill
  cargo run --release --example serve"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("devices") => {
            println!(
                "{:<18} {:<22} {:>10} {:>10} {:>8} {:>8} {:>9}",
                "backend", "form factor", "INT8 TOPS", "F16 TF", "peak W", "price", "calib"
            );
            for b in all_backends() {
                println!(
                    "{:<18} {:<22} {:>10.1} {:>10.1} {:>8.1} {:>7.0}€ {:>9}",
                    b.name,
                    b.device.form_factor,
                    b.device.tops_int8,
                    b.device.tflops_fp16.max(b.device.tflops_bf16),
                    b.device.peak_w,
                    b.device.price_eur,
                    format!("{:?}", b.calib).chars().take(9).collect::<String>(),
                );
            }
        }
        Some("config") => {
            for (name, c) in [
                ("cifar (Table 7)", Curriculum::cifar()),
                ("segmentation (Table 7)", Curriculum::seg()),
                ("transformer (Table 8)", Curriculum::transformer()),
            ] {
                println!(
                    "{name}: E_w={} E_f={} H={} lam_max={} p_clip={} K={} mu={}",
                    c.e_w, c.e_f, c.horizon, c.lam_max, c.p_clip, c.prune_every, c.mu
                );
            }
        }
        Some("lambda") => {
            if args.len() != 5 {
                usage();
            }
            let c = Curriculum {
                e_w: args[1].parse()?,
                e_f: args[2].parse()?,
                horizon: args[3].parse()?,
                ..Curriculum::cifar()
            };
            let epochs: usize = args[4].parse()?;
            for t in 0..epochs {
                println!("{t} {:.6}", c.lam(t));
            }
        }
        Some("backend") => {
            let Some(name) = args.get(1) else { usage() };
            let Some(b) = backend_by_name(name) else {
                bail!("unknown backend {name}; see `quant-trim devices`")
            };
            println!("{b:#?}");
        }
        _ => usage(),
    }
    Ok(())
}
