//! Synthetic dataset generators standing in for CIFAR-10/100 and COCO
//! (DESIGN.md §2: no datasets ship offline; these are *learnable* procedural
//! tasks that exercise the same code paths — real gradient-based convergence,
//! heavy-tailed activations, long-tailed segmentation statistics).
//!
//! Classification ("CIFAR-like"): each class is a distinct mixture of
//! oriented sinusoidal gratings + a class-colored blob, plus per-image phase/
//! position jitter, Gaussian noise, and rare high-amplitude outlier pixels
//! (the activation-outlier stressor the paper's method targets).
//!
//! Segmentation ("COCO-like"): random circles/rectangles of class-specific
//! texture on a background; labels are per-pixel class ids with a long-tailed
//! class frequency distribution.

use crate::tensor::Tensor;
use crate::testutil::Rng;

/// A batch: images (N, C, H, W) + integer labels (classification: N;
/// segmentation: N*H*W).
pub struct Batch {
    pub images: Tensor,
    pub labels: Vec<i32>,
}

#[derive(Clone, Copy, Debug)]
pub struct ClsSpec {
    pub classes: usize,
    pub image: usize,
    pub outlier_p: f32,
}

impl ClsSpec {
    pub fn cifar100() -> Self {
        ClsSpec { classes: 100, image: 32, outlier_p: 0.002 }
    }
    pub fn cifar10() -> Self {
        ClsSpec { classes: 10, image: 32, outlier_p: 0.002 }
    }
    /// Smallest spec the synth CNNs accept (8x8 images) — keeps native
    /// training fast enough for debug-mode `cargo test`.
    pub fn tiny() -> Self {
        ClsSpec { classes: 10, image: 8, outlier_p: 0.002 }
    }
}

/// Deterministic class "style" parameters derived from the class id.
fn class_style(class: usize) -> (f32, f32, f32, [f32; 3]) {
    let mut r = Rng::new(0xC1A55 + class as u64 * 7919);
    let freq = 0.2 + 0.8 * r.uniform(); // grating frequency
    let theta = std::f32::consts::PI * r.uniform(); // orientation
    let phase2 = std::f32::consts::PI * r.uniform();
    let color = [r.uniform(), r.uniform(), r.uniform()];
    (freq, theta, phase2, color)
}

/// Generate one classification batch. `seed` controls jitter/noise; the same
/// (seed, spec, n) is bit-reproducible.
pub fn gen_cls_batch(spec: ClsSpec, n: usize, seed: u64) -> Batch {
    let s = spec.image;
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1));
    let mut images = Tensor::zeros(&[n, 3, s, s]);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = rng.below(spec.classes);
        labels.push(class as i32);
        let (freq, theta, phase2, color) = class_style(class);
        let jx = rng.range(-3.0, 3.0);
        let jy = rng.range(-3.0, 3.0);
        let jphase = rng.range(0.0, std::f32::consts::PI);
        let (ct, st) = (theta.cos(), theta.sin());
        // class-colored blob position
        let bx = s as f32 * (0.3 + 0.4 * rng.uniform());
        let by = s as f32 * (0.3 + 0.4 * rng.uniform());
        let br = s as f32 * 0.18;
        for y in 0..s {
            for x in 0..s {
                let xf = x as f32 + jx;
                let yf = y as f32 + jy;
                let u = ct * xf + st * yf;
                let v = -st * xf + ct * yf;
                let grating =
                    (freq * u + jphase).sin() * 0.5 + (freq * 1.7 * v + phase2).cos() * 0.3;
                let d2 = (x as f32 - bx).powi(2) + (y as f32 - by).powi(2);
                let blob = (-d2 / (br * br)).exp();
                for c in 0..3 {
                    let mut val = grating * (0.6 + 0.4 * color[c]) + blob * (color[c] * 2.0 - 1.0);
                    val += rng.normal() * 0.15; // pixel noise
                    if rng.uniform() < spec.outlier_p {
                        // rare high-amplitude pixels: the activation-outlier
                        // stressor (≈3x the signal range, CIFAR-realistic —
                        // heavier tails make the FP32-vs-INT comparison
                        // degenerate for ANY method)
                        val += rng.normal() * 2.5;
                    }
                    images.data[((i * 3 + c) * s + y) * s + x] = val;
                }
            }
        }
    }
    Batch { images, labels }
}

#[derive(Clone, Copy, Debug)]
pub struct SegSpec {
    pub classes: usize,
    pub image: usize,
    pub max_objects: usize,
}

impl SegSpec {
    pub fn coco_like() -> Self {
        SegSpec { classes: 8, image: 64, max_objects: 5 }
    }
}

/// Generate one segmentation batch: labels are per-pixel (class 0 =
/// background). Class frequencies are long-tailed (Zipf-ish), as in COCO.
pub fn gen_seg_batch(spec: SegSpec, n: usize, seed: u64) -> Batch {
    let s = spec.image;
    let mut rng = Rng::new(seed.wrapping_mul(0xB5297A4D).max(1));
    let mut images = Tensor::zeros(&[n, 3, s, s]);
    let mut labels = vec![0i32; n * s * s];
    for i in 0..n {
        // textured background
        let bgf = rng.range(0.05, 0.15);
        for y in 0..s {
            for x in 0..s {
                let v = (bgf * (x as f32 + y as f32)).sin() * 0.2;
                for c in 0..3 {
                    images.data[((i * 3 + c) * s + y) * s + x] = v + rng.normal() * 0.1;
                }
            }
        }
        let objects = 1 + rng.below(spec.max_objects);
        for _ in 0..objects {
            // Zipf-ish class draw over 1..classes (0 is background)
            let z = rng.uniform();
            let class = 1 + ((spec.classes - 1) as f32 * z * z) as usize;
            let class = class.min(spec.classes - 1);
            let (freq, theta, _, color) = class_style(class + 1000);
            let cx = rng.below(s) as f32;
            let cy = rng.below(s) as f32;
            let r = rng.range(4.0, s as f32 * 0.3);
            let is_rect = rng.uniform() < 0.5;
            let (ct, st) = (theta.cos(), theta.sin());
            for y in 0..s {
                for x in 0..s {
                    let dx = x as f32 - cx;
                    let dy = y as f32 - cy;
                    let inside = if is_rect {
                        dx.abs() < r && dy.abs() < r * 0.6
                    } else {
                        dx * dx + dy * dy < r * r
                    };
                    if inside {
                        labels[(i * s + y) * s + x] = class as i32;
                        let u = ct * dx + st * dy;
                        let tex = (freq * 3.0 * u).sin() * 0.4;
                        for c in 0..3 {
                            images.data[((i * 3 + c) * s + y) * s + x] =
                                color[c] * 1.5 - 0.5 + tex + rng.normal() * 0.08;
                        }
                    }
                }
            }
        }
    }
    Batch { images, labels }
}

/// A deterministic epoch of batches: batch b of epoch e uses seed
/// f(base, e, b) so training data is reproducible but non-repeating.
pub fn epoch_seeds(base: u64, epoch: usize, batches: usize) -> Vec<u64> {
    (0..batches).map(|b| base ^ ((epoch as u64) << 32) ^ (b as u64 + 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cls_batch_deterministic_and_shaped() {
        let spec = ClsSpec::cifar10();
        let a = gen_cls_batch(spec, 4, 42);
        let b = gen_cls_batch(spec, 4, 42);
        assert_eq!(a.images.shape, vec![4, 3, 32, 32]);
        assert_eq!(a.images.data, b.images.data);
        assert_eq!(a.labels, b.labels);
        let c = gen_cls_batch(spec, 4, 43);
        assert_ne!(a.images.data, c.images.data);
    }

    #[test]
    fn cls_labels_in_range_and_varied() {
        let spec = ClsSpec::cifar100();
        let b = gen_cls_batch(spec, 64, 7);
        assert!(b.labels.iter().all(|&l| (0..100).contains(&l)));
        let distinct: std::collections::HashSet<_> = b.labels.iter().collect();
        assert!(distinct.len() > 10, "labels should be varied");
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean image distance between two classes should exceed within-class
        let spec = ClsSpec::cifar10();
        let mut per_class: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 2];
        let mut seed = 0;
        while per_class[0].len() < 3 || per_class[1].len() < 3 {
            seed += 1;
            let b = gen_cls_batch(spec, 8, seed);
            for i in 0..8 {
                let cls = b.labels[i] as usize;
                if cls < 2 && per_class[cls].len() < 3 {
                    let img = b.images.data[i * 3 * 32 * 32..(i + 1) * 3 * 32 * 32].to_vec();
                    per_class[cls].push(img);
                }
            }
        }
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let within = dist(&per_class[0][0], &per_class[0][1]);
        let between = dist(&per_class[0][0], &per_class[1][0]);
        assert!(between > within * 0.5, "classes should differ: w={within} b={between}");
    }

    #[test]
    fn seg_batch_has_background_and_objects() {
        let spec = SegSpec::coco_like();
        let b = gen_seg_batch(spec, 2, 11);
        assert_eq!(b.labels.len(), 2 * 64 * 64);
        let bg = b.labels.iter().filter(|&&l| l == 0).count();
        let fg = b.labels.len() - bg;
        assert!(bg > 0 && fg > 0, "bg {bg} fg {fg}");
        assert!(b.labels.iter().all(|&l| (0..8).contains(&l)));
    }

    #[test]
    fn outliers_present_at_configured_rate() {
        let spec = ClsSpec { classes: 10, image: 32, outlier_p: 0.01 };
        let b = gen_cls_batch(spec, 16, 3);
        let extremes = b.images.data.iter().filter(|v| v.abs() > 2.5).count();
        assert!(extremes > 0, "heavy-tail pixels expected");
    }
}
