//! Quant-Trim: hardware-neutral low-bit training and cross-backend edge-NPU
//! deployment, reproducing Dhahri & Urban, *"Quant-Trim in Practice"* (2025).
//!
//! Three-layer architecture (see DESIGN.md):
//!
//! * **Layer 1/2 (build time)** — Pallas kernels + JAX training graphs,
//!   AOT-lowered to HLO text under `artifacts/` by `make artifacts`.
//! * **Layer 3 (this crate)** — the runtime: a PJRT-backed training
//!   coordinator ([`coordinator`]), a graph IR + bit-exact integer inference
//!   engine ([`qir`], [`engine`]), calibration/PTQ baselines ([`calib`]),
//!   and a fleet of simulated vendor NPU backends ([`backends`]) with
//!   roofline latency/power models ([`perfmodel`]).
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! and all examples are self-contained.

// Every unsafe operation must sit in its own `unsafe` block with a
// `// SAFETY:` obligation (clippy::undocumented_unsafe_blocks enforces the
// comments in CI's lint job; the audit gate greps both).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod backends;
pub mod calib;
pub mod ckpt;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod metrics;
pub mod perfmodel;
pub mod qir;
pub mod runtime;
pub mod tensor;
pub mod testutil;
