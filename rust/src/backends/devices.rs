//! The device fleet (paper Tables 4-6, 10; anonymized Hardware A-D plus the
//! named NVIDIA / Rockchip parts). Specs follow the paper's Table 6 numbers;
//! per-compiler quirks follow Table 4 and §A.1.

use crate::calib::CalibMethod;
use crate::perfmodel::{DeviceSpec, Precision};
use crate::tensor::{QuantScheme, RoundMode};

use super::BackendSpec;

/// Stable identifiers for the fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    HardwareA,
    HardwareB,
    HardwareC,
    HardwareD,
    JetsonOrinNano,
    JetsonAgxOrin,
    Rk3588,
    Rtx3090,
}

/// Hardware A: M.2 NPU, 26 TOPS INT8, SRAM-only, ~2.5-5 W. Strict static
/// W8/A8, per-tensor weights, DSP-style rounding, percentile calibration,
/// calibration REQUIRED for INT. Transformer attention unsupported -> host.
fn hardware_a() -> BackendSpec {
    BackendSpec {
        name: "hardware_a",
        device: DeviceSpec {
            name: "Hardware A",
            form_factor: "M.2 2280 (B/M)",
            link: "PCIe Gen3 x2",
            tops_int4: 52.0,
            tops_int8: 26.0,
            tflops_bf16: 0.0,
            tflops_fp16: 0.0,
            tflops_fp32: 0.0,
            efficiency: 0.50,
            // on-chip SRAM only (paper Table 6 note): activations never leave
            // the die, so effective tiling bandwidth is SRAM-class — this is
            // what lets it beat DRAM-bound SoCs on large-activation graphs
            mem_bw_gbs: 60.0,
            pcie_gbs: Some(2.0),
            idle_w: 1.0,
            peak_w: 5.0,
            price_eur: 150.0,
            op_overhead_us: 6.0,
            fallback_ms: 2.5,
        },
        precisions: vec![Precision::Int8, Precision::Int4],
        weight_bits: &[8, 4],
        supports_dynamic_act: false,
        weight_scheme: QuantScheme::PerTensorSym,
        round: RoundMode::HalfAway,
        calib: CalibMethod::Percentile(0.999),
        accepts_qat_scales: true,
        unsupported: &["attention", "layernorm", "gelu", "tokmean", "to_tokens"],
        fuses_activations: true,
        runtime_boost: 1.0,
        needs_calib_for_int: true,
    }
}

/// Hardware B: M.2 module of 4 chips, 6 TOPS/chip, 0.5-2 W/chip. Hybrid
/// W8 (per-channel) / BF16 activations — no calibration dataset needed.
fn hardware_b() -> BackendSpec {
    BackendSpec {
        name: "hardware_b",
        device: DeviceSpec {
            name: "Hardware B",
            form_factor: "M.2 module (4 chips)",
            link: "PCIe Gen3 x4 / USB3",
            tops_int4: 0.0,
            tops_int8: 24.0,
            tflops_bf16: 6.0,
            tflops_fp16: 0.0,
            tflops_fp32: 0.0,
            efficiency: 0.35,
            mem_bw_gbs: 16.0,
            pcie_gbs: Some(3.5),
            idle_w: 1.5,
            peak_w: 5.0,
            price_eur: 125.0,
            op_overhead_us: 8.0,
            fallback_ms: 2.0,
        },
        precisions: vec![Precision::Bf16, Precision::Int8],
        weight_bits: &[8],
        supports_dynamic_act: false,
        weight_scheme: QuantScheme::PerChannelSym,
        round: RoundMode::TiesEven,
        calib: CalibMethod::MinMax,
        accepts_qat_scales: true,
        unsupported: &["attention", "gelu"],
        fuses_activations: true,
        runtime_boost: 1.0,
        needs_calib_for_int: false,
    }
}

/// Hardware C: full SoC (RK3588-class but distinct vendor), INT8/FP16,
/// entropy calibration, conditional calib. Modest NPU, rich op coverage.
fn hardware_c() -> BackendSpec {
    BackendSpec {
        name: "hardware_c",
        device: DeviceSpec {
            name: "Hardware C",
            form_factor: "Full SoC",
            link: "unified DRAM",
            tops_int4: 0.0,
            tops_int8: 6.0,
            tflops_bf16: 0.0,
            tflops_fp16: 1.5,
            tflops_fp32: 0.0,
            efficiency: 0.30,
            mem_bw_gbs: 14.0,
            pcie_gbs: None,
            idle_w: 2.5,
            peak_w: 8.0,
            price_eur: 250.0,
            op_overhead_us: 15.0,
            fallback_ms: 0.4, // same memory space: cheap fallback
        },
        precisions: vec![Precision::Int8, Precision::Fp16],
        weight_bits: &[8],
        supports_dynamic_act: false,
        weight_scheme: QuantScheme::PerChannelSym,
        round: RoundMode::TiesEven,
        calib: CalibMethod::Entropy,
        accepts_qat_scales: false,
        unsupported: &["gelu"],
        fuses_activations: true,
        runtime_boost: 1.0,
        needs_calib_for_int: true,
    }
}

/// Hardware D: low-profile PCIe, 60 TOPS INT8 / ~30 TFLOPS BF16, 8-10 W.
/// Compiler-provided static scaling (MSE search), per-channel weights,
/// no user calibration dataset required.
fn hardware_d() -> BackendSpec {
    BackendSpec {
        name: "hardware_d",
        device: DeviceSpec {
            name: "Hardware D",
            form_factor: "Low-profile PCIe",
            link: "PCIe Gen3 x8",
            tops_int4: 120.0,
            tops_int8: 60.0,
            tflops_bf16: 30.0,
            tflops_fp16: 0.0,
            tflops_fp32: 0.0,
            efficiency: 0.40,
            mem_bw_gbs: 32.0,
            pcie_gbs: Some(7.0),
            idle_w: 3.0,
            peak_w: 10.0,
            price_eur: 350.0,
            op_overhead_us: 5.0,
            fallback_ms: 1.5,
        },
        precisions: vec![Precision::Int8, Precision::Bf16, Precision::Int4],
        weight_bits: &[8, 4],
        supports_dynamic_act: true,
        weight_scheme: QuantScheme::PerChannelSym,
        round: RoundMode::TiesEven,
        calib: CalibMethod::Mse,
        accepts_qat_scales: true,
        unsupported: &[],
        fuses_activations: true,
        runtime_boost: 1.0,
        needs_calib_for_int: false,
    }
}

/// Jetson Orin Nano 8GB: SoC GPU, TensorRT FP32/FP16/INT8 (entropy calib),
/// per-channel. TensorRT-class runtime: can recompute activation ranges per
/// batch, so dynamic-scaling deployments are native (at the modelled
/// per-node range-scan cost).
fn jetson_orin_nano() -> BackendSpec {
    BackendSpec {
        name: "jetson_orin_nano",
        device: DeviceSpec {
            name: "Jetson Orin Nano 8GB",
            form_factor: "SoC (SOM)",
            link: "unified LPDDR5",
            tops_int4: 0.0,
            tops_int8: 20.0,
            tflops_bf16: 0.0,
            tflops_fp16: 5.0, // dense (vendor quotes 10 with 2:4 sparsity)
            tflops_fp32: 2.5,
            efficiency: 0.35,
            mem_bw_gbs: 68.0,
            pcie_gbs: None,
            idle_w: 4.0,
            peak_w: 10.0,
            price_eur: 250.0,
            op_overhead_us: 12.0,
            fallback_ms: 0.2,
        },
        precisions: vec![Precision::Int8, Precision::Fp16, Precision::Fp32],
        weight_bits: &[8],
        supports_dynamic_act: true,
        weight_scheme: QuantScheme::PerChannelSym,
        round: RoundMode::TiesEven,
        calib: CalibMethod::Entropy,
        accepts_qat_scales: true,
        unsupported: &[],
        fuses_activations: true,
        runtime_boost: 2.6, // TensorRT vs naive CUDA dispatch
        needs_calib_for_int: true,
    }
}

/// Jetson AGX Orin: the big SoC sibling.
fn jetson_agx_orin() -> BackendSpec {
    BackendSpec {
        name: "jetson_agx_orin",
        device: DeviceSpec {
            name: "Jetson AGX Orin",
            form_factor: "SoC (SOM)",
            link: "unified LPDDR5",
            tops_int4: 275.0,
            tops_int8: 137.0,
            tflops_bf16: 0.0,
            tflops_fp16: 42.0,
            tflops_fp32: 10.6,
            efficiency: 0.35,
            mem_bw_gbs: 204.0,
            pcie_gbs: None,
            idle_w: 10.0,
            peak_w: 40.0,
            price_eur: 1800.0,
            op_overhead_us: 10.0,
            fallback_ms: 0.2,
        },
        precisions: vec![Precision::Int8, Precision::Fp16, Precision::Fp32, Precision::Int4],
        weight_bits: &[8, 4],
        supports_dynamic_act: true,
        weight_scheme: QuantScheme::PerChannelSym,
        round: RoundMode::TiesEven,
        calib: CalibMethod::Entropy,
        accepts_qat_scales: true,
        unsupported: &[],
        fuses_activations: true,
        runtime_boost: 2.6,
        needs_calib_for_int: true,
    }
}

/// RK3588 (RKNN): SoC NPU, INT8 per-tensor *asymmetric-ish* minmax
/// calibration (most outlier-fragile), FP16 fallback mode, DSP rounding.
fn rk3588() -> BackendSpec {
    BackendSpec {
        name: "rk3588",
        device: DeviceSpec {
            name: "RK3588 (RKNN)",
            form_factor: "Full SoC",
            link: "unified LPDDR4x",
            tops_int4: 0.0,
            tops_int8: 6.0,
            tflops_bf16: 0.0,
            tflops_fp16: 1.0,
            tflops_fp32: 0.0,
            efficiency: 0.25, // compiler maturity (paper Table 5 watch-outs)
            mem_bw_gbs: 19.0,
            pcie_gbs: None,
            idle_w: 2.0,
            peak_w: 8.0,
            price_eur: 120.0,
            op_overhead_us: 20.0,
            fallback_ms: 0.5,
        },
        precisions: vec![Precision::Int8, Precision::Fp16],
        weight_bits: &[8],
        supports_dynamic_act: false,
        weight_scheme: QuantScheme::PerTensorSym,
        round: RoundMode::HalfAway,
        calib: CalibMethod::MinMax,
        accepts_qat_scales: false,
        unsupported: &["attention", "layernorm", "gelu", "tokmean", "to_tokens"],
        // RKNN-class compiler maturity: dispatches activations as their own
        // ops instead of fusing them into the conv epilogue
        fuses_activations: false,
        runtime_boost: 1.0,
        needs_calib_for_int: true,
    }
}

/// RTX 3090 desktop GPU — the paper's Table 10 comparison point.
fn rtx3090() -> BackendSpec {
    BackendSpec {
        name: "rtx3090",
        device: DeviceSpec {
            name: "RTX 3090",
            form_factor: "Desktop GPU",
            link: "PCIe Gen4 x16",
            tops_int4: 568.0,
            tops_int8: 284.0,
            tflops_bf16: 71.0,
            tflops_fp16: 71.0,
            tflops_fp32: 35.6,
            efficiency: 0.45,
            mem_bw_gbs: 936.0,
            pcie_gbs: Some(25.0),
            idle_w: 25.0,
            peak_w: 190.0,
            price_eur: 1500.0,
            op_overhead_us: 8.0,
            fallback_ms: 0.1,
        },
        precisions: vec![Precision::Fp16, Precision::Fp32, Precision::Int8, Precision::Int4],
        weight_bits: &[8, 4],
        supports_dynamic_act: true,
        weight_scheme: QuantScheme::PerChannelSym,
        round: RoundMode::TiesEven,
        calib: CalibMethod::Entropy,
        accepts_qat_scales: true,
        unsupported: &[],
        fuses_activations: true,
        runtime_boost: 2.6,
        needs_calib_for_int: true,
    }
}

/// The full fleet in paper order.
pub fn all_backends() -> Vec<BackendSpec> {
    vec![
        hardware_a(),
        hardware_b(),
        hardware_c(),
        hardware_d(),
        jetson_orin_nano(),
        jetson_agx_orin(),
        rk3588(),
        rtx3090(),
    ]
}

pub fn backend_by_name(name: &str) -> Option<BackendSpec> {
    all_backends().into_iter().find(|b| b.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_covers_paper_tables() {
        let fleet = all_backends();
        assert_eq!(fleet.len(), 8);
        // Table 6 sanity: Hardware A 26 TOPS ~2.5-5W, D 60 TOPS 8-10W
        let a = backend_by_name("hardware_a").unwrap();
        assert_eq!(a.device.tops_int8, 26.0);
        assert!(a.device.peak_w <= 5.0);
        let d = backend_by_name("hardware_d").unwrap();
        assert_eq!(d.device.tops_int8, 60.0);
        // Table 4: B is hybrid W8/ABF16 and needs no calibration
        let b = backend_by_name("hardware_b").unwrap();
        assert_eq!(b.default_precision(), Precision::Bf16);
        assert!(!b.needs_calib_for_int);
        // NPUs stay in single-digit watts; GPU pulls ~200
        for be in &fleet {
            if be.name.starts_with("hardware_") {
                assert!(be.device.peak_w <= 10.0, "{} too hungry", be.name);
            }
        }
        assert!(backend_by_name("rtx3090").unwrap().device.peak_w >= 150.0);
    }

    #[test]
    fn int4_capability_is_a_fleet_axis() {
        // sub-byte kernels are a capability, not a given: part of the fleet
        // has native INT4 MAC arrays, the rest must fall back to INT8
        for be in all_backends() {
            let has4 = be.supports_weight_bits(4);
            assert_eq!(has4, be.precisions.contains(&Precision::Int4), "{}", be.name);
            assert_eq!(has4, be.device.tops_int4 > 0.0, "{}", be.name);
            assert!(be.supports_weight_bits(8), "{}: every backend has i8", be.name);
            // default precision is never the sub-byte one
            assert_ne!(be.default_precision(), Precision::Int4, "{}", be.name);
        }
        assert!(backend_by_name("hardware_a").unwrap().supports_weight_bits(4));
        assert!(backend_by_name("hardware_d").unwrap().supports_weight_bits(4));
        assert!(!backend_by_name("rk3588").unwrap().supports_weight_bits(4));
        assert!(!backend_by_name("hardware_b").unwrap().supports_weight_bits(4));
    }

    #[test]
    fn dynamic_act_scaling_is_a_fleet_axis() {
        // runtime range recomputation is a capability, not a given: the
        // TensorRT-class runtimes and the mature PCIe NPU support it, the
        // strict-static compilers do not (paper Table 4's static/dynamic
        // "Act. scaling @ inference" column)
        for name in ["jetson_orin_nano", "jetson_agx_orin", "rtx3090", "hardware_d"] {
            assert!(backend_by_name(name).unwrap().supports_dynamic_act, "{name}");
        }
        for name in ["hardware_a", "hardware_b", "hardware_c", "rk3588"] {
            assert!(!backend_by_name(name).unwrap().supports_dynamic_act, "{name}");
        }
        // both capability classes exist in the fleet — the deploy matrix's
        // static-vs-dynamic column always shows native AND fallback cells
        let fleet = all_backends();
        assert!(fleet.iter().any(|b| b.supports_dynamic_act));
        assert!(fleet.iter().any(|b| !b.supports_dynamic_act));
    }

    #[test]
    fn vendor_quirks_differ() {
        // the cross-backend variance the paper targets: different rounding,
        // schemes and calibration across the fleet
        let fleet = all_backends();
        // epilogue fusion is a maturity axis too: most stacks fuse, RKNN
        // (the paper's Table 5 watch-out) does not
        assert!(!backend_by_name("rk3588").unwrap().fuses_activations);
        assert!(backend_by_name("hardware_d").unwrap().fuses_activations);
        let rounds: std::collections::HashSet<_> =
            fleet.iter().map(|b| format!("{:?}", b.round)).collect();
        let schemes: std::collections::HashSet<_> =
            fleet.iter().map(|b| format!("{:?}", b.weight_scheme)).collect();
        let calibs: std::collections::HashSet<_> =
            fleet.iter().map(|b| format!("{:?}", b.calib)).collect();
        assert!(rounds.len() > 1 && schemes.len() > 1 && calibs.len() >= 3);
    }
}
