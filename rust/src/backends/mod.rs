//! Simulated vendor NPU/GPU backends (paper §A.1/A.2, Tables 4-6).
//!
//! Each backend is a "black-box compiler": it takes the hardware-neutral
//! checkpoint (QIR graph + float params + optional embedded QAT stats) and
//! makes its own opaque choices — weight scheme (per-channel vs per-tensor),
//! rounding mode, activation precision, calibration observer, operator
//! coverage. This is exactly the heterogeneity the paper's method is designed
//! to be robust to; the accuracy consequences are evaluated with the
//! bit-exact integer engine, the latency/power consequences with the
//! roofline perf model.

/// The simulated device fleet (paper Tables 4-6 specs).
pub mod devices;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::calib::{self, CalibMethod, Calibration};
use crate::engine::verify::AuditReport;
use crate::engine::{ActMode, CompiledModel, ExecConfig, WeightMode};
use crate::perfmodel::{self, ActScaling, PerfReport, Precision};
use crate::qir::{passes, Graph};
use crate::tensor::{QWeight, QuantScheme, RoundMode, Tensor};

pub use devices::{all_backends, backend_by_name, BackendKind};

/// Where activation ranges come from at compile time (paper Table 4
/// "Act. scaling @ inference").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RangeSource {
    /// Offline calibration on a representative dataset.
    Calibration,
    /// QAT statistics embedded in the checkpoint (Quant-Trim qstate).
    QatScales,
}

/// One vendor toolchain's fixed choices.
#[derive(Clone, Debug)]
pub struct BackendSpec {
    /// Stable backend name (e.g. "hardware_a", "rk3588").
    pub name: &'static str,
    /// Device capability sheet behind the roofline perf model.
    pub device: perfmodel::DeviceSpec,
    /// Precisions this toolchain can compile for (first = default).
    pub precisions: Vec<Precision>,
    /// Weight bit-widths this toolchain has kernels for. Every backend has
    /// 8; only parts with native sub-byte MAC arrays list 4. Requesting an
    /// INT4 deployment on a backend without 4 falls back to INT8 (the
    /// TruncQuant observation: sub-byte support is exactly where backends
    /// diverge, so it is modelled per backend, never assumed).
    pub weight_bits: &'static [u8],
    /// Whether the runtime can recompute activation ranges from the live
    /// batch ("dynamic activation scaling", paper Table 4). Like sub-byte
    /// kernels this is a capability, not a given: strict-static compilers
    /// bake every range at compile time, and a dynamic request on them
    /// falls back to static (recorded on the `Deployment`).
    pub supports_dynamic_act: bool,
    /// Weight quantization granularity (per-channel vs per-tensor).
    pub weight_scheme: QuantScheme,
    /// Rounding mode of the toolchain's quantizers.
    pub round: RoundMode,
    /// Range-estimation observer the compiler runs over calibration data.
    pub calib: CalibMethod,
    /// Whether the compiler can consume embedded QAT scales.
    pub accepts_qat_scales: bool,
    /// Node kinds this toolchain cannot map to its kernels (host fallback).
    pub unsupported: &'static [&'static str],
    /// Whether the compiler fuses conv→bn→activation into one kernel with
    /// an epilogue (mature stacks do; immature ones dispatch the activation
    /// as its own op and pay the per-op overhead).
    pub fuses_activations: bool,
    /// Runtime efficiency boost of the vendor's compiled runtime vs naive
    /// kernel dispatch (TensorRT vs CUDA on NVIDIA parts).
    pub runtime_boost: f64,
    /// Whether an INT deployment *requires* a calibration dataset
    /// (Table 4 "PTQ calib.").
    pub needs_calib_for_int: bool,
}

/// Inputs to a backend compile: the hardware-neutral checkpoint contents.
pub struct CheckpointView<'a> {
    /// Hardware-neutral QIR graph.
    pub graph: &'a Graph,
    /// Float parameters keyed like the graph's weight nodes.
    pub params: &'a BTreeMap<String, Tensor>,
    /// BatchNorm running statistics (folded away during compile).
    pub bn: &'a BTreeMap<String, Tensor>,
    /// Quant-Trim QAT statistics (empty for MAP checkpoints).
    pub qstate: &'a BTreeMap<String, Tensor>,
}

/// Extra PTQ tricks a deployment may enable (Table 3 baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct PtqOptions {
    /// Cross-layer equalization before weight quantization.
    pub equalization: bool,
    /// AdaRound-style rounding refinement on calibration data (i8 only).
    pub adaround: bool,
}

/// A compiled deployment: the executable model + modelled edge metrics.
pub struct Deployment {
    /// The backend-compiled, plan-backed executable model.
    pub model: CompiledModel,
    /// Precision the deployment actually runs at (the *effective* one —
    /// differs from `requested` when the backend lacked sub-byte kernels).
    pub precision: Precision,
    /// Precision the caller asked for.
    pub requested: Precision,
    /// Activation scaling the deployment actually runs (`Dynamic` only when
    /// the backend supports it *and* the precision has integer activations;
    /// float-activation deployments always record `Static` — there are no
    /// requantization points to scale).
    pub act_scaling: ActScaling,
    /// Activation scaling the caller asked for.
    pub requested_scaling: ActScaling,
    /// Name of the vendor backend that compiled this deployment.
    pub backend: &'static str,
    /// Modelled batch-1 latency/power/energy on the simulated device.
    pub perf_b1: PerfReport,
}

impl Deployment {
    /// Run the static plan auditor (`engine::verify`) over this compiled
    /// deployment: plan liveness/aliasing replay, qparam sanity, and
    /// interval / accumulator-overflow analysis at this deployment's actual
    /// precision and scaling. `input` is the worst-case (lo, hi) input
    /// range; `None` uses the default normalized-image interval.
    pub fn audit(&self, input: Option<(f32, f32)>) -> Result<AuditReport> {
        self.model.audit(input)
    }

    /// True when an INT4 request was compiled at INT8 for lack of kernels.
    pub fn fell_back(&self) -> bool {
        self.requested != self.precision
    }

    /// True when a dynamic-scaling request compiled with static compile-time
    /// ranges (backend without runtime range support, or a float-activation
    /// precision with nothing to rescale).
    pub fn scaling_fell_back(&self) -> bool {
        self.requested_scaling != self.act_scaling
    }
}

impl BackendSpec {
    /// The precision this toolchain deploys when none is requested.
    pub fn default_precision(&self) -> Precision {
        self.precisions[0]
    }

    /// Whether this toolchain ships kernels for a weight bit-width.
    pub fn supports_weight_bits(&self, bits: u8) -> bool {
        self.weight_bits.contains(&bits)
    }

    /// Compile the checkpoint for this backend at the given precision, with
    /// static activation scaling.
    ///
    /// `calib_batches` may be empty only if the backend doesn't require
    /// calibration (BF16/FP16 paths, or QAT-scale consumption).
    pub fn compile(
        &self,
        ckpt: CheckpointView<'_>,
        precision: Precision,
        range_source: RangeSource,
        calib_batches: &[Tensor],
        ptq: PtqOptions,
    ) -> Result<Deployment> {
        self.compile_scaled(ckpt, precision, ActScaling::Static, range_source, calib_batches, ptq)
    }

    /// [`Self::compile`] with the activation-scaling axis exposed.
    ///
    /// Requesting [`ActScaling::Dynamic`] on a backend with
    /// `supports_dynamic_act` and an integer-activation precision compiles a
    /// **calibration-free** deployment (`ActMode::DynInt8`): no calibration
    /// run, no range propagation, empty `act_ranges` — ranges come from the
    /// live batch at serve time. On any other backend/precision combination
    /// the request falls back to static scaling (recorded on the
    /// `Deployment`, like the INT4→INT8 weight fallback).
    pub fn compile_scaled(
        &self,
        ckpt: CheckpointView<'_>,
        precision: Precision,
        scaling: ActScaling,
        range_source: RangeSource,
        calib_batches: &[Tensor],
        ptq: PtqOptions,
    ) -> Result<Deployment> {
        let requested = precision;
        let requested_scaling = scaling;
        // sub-byte fallback: a backend without int4 kernels deploys the
        // requested graph at INT8 instead of refusing it outright (the
        // deployment records both precisions so matrices can show the gap)
        let precision = if precision == Precision::Int4 && !self.supports_weight_bits(4) {
            Precision::Int8
        } else {
            precision
        };
        if !self.precisions.contains(&precision) {
            bail!("backend {} does not support {:?}", self.name, precision);
        }
        // 1. every toolchain folds BN first; mature stacks also fuse the
        //    conv's sole-consumer activation into the kernel epilogue
        let (graph, mut params, fold_factors) = if self.fuses_activations {
            let (g, p, f, _fused) = passes::fuse_conv_bn_act(ckpt.graph, ckpt.params, ckpt.bn)?;
            (g, p, f)
        } else {
            passes::fold_bn(ckpt.graph, ckpt.params, ckpt.bn)?
        };

        // 2. optional cross-layer equalization (PTQ baseline)
        if ptq.equalization {
            passes::cross_layer_equalization(&graph, &mut params);
        }

        let (weight_mode, mut act_mode) = match precision {
            Precision::Int4 => (WeightMode::Int4, ActMode::Int8 { round: self.round }), // W4/A8
            Precision::Int8 => (WeightMode::Int8, ActMode::Int8 { round: self.round }),
            Precision::Bf16 => (WeightMode::Int8, ActMode::Bf16), // W8/ABF16 hybrid
            Precision::Fp16 => (WeightMode::F32, ActMode::F16),
            Precision::Fp32 => (WeightMode::F32, ActMode::F32),
        };
        // dynamic activation scaling: a capability, like sub-byte kernels —
        // honoured only when the runtime can recompute ranges per batch AND
        // the precision has integer activations; otherwise fall back to
        // static compile-time scaling (recorded on the Deployment)
        let act_scaling = match act_mode {
            ActMode::Int8 { round }
                if scaling == ActScaling::Dynamic && self.supports_dynamic_act =>
            {
                act_mode = ActMode::DynInt8 { round };
                ActScaling::Dynamic
            }
            _ => ActScaling::Static,
        };
        let wbits = weight_mode.weight_bits();

        // 3. activation ranges (static INT8 only — a dynamic deployment
        //    computes ranges from the live batch and needs no calibration)
        let mut calibration = Calibration::default();
        if matches!(act_mode, ActMode::Int8 { .. }) {
            let use_qat =
                range_source == RangeSource::QatScales && self.accepts_qat_scales && !ckpt.qstate.is_empty();
            if !use_qat && calib_batches.is_empty() && self.needs_calib_for_int {
                bail!("backend {} requires a calibration dataset for INT8", self.name);
            }
            // compiler statistics pass: even QAT-scale deployments run the
            // compiler's own observer for tensors without embedded scales
            if !calib_batches.is_empty() {
                let fp = crate::engine::fp32_model(graph.clone(), params.clone(), BTreeMap::new());
                calibration = calib::calibrate(&fp, calib_batches, self.calib)?;
            }
            if use_qat {
                // embedded QAT scales take precedence at the quantization
                // points the checkpoint trained (aq nodes)
                let qat = calib::ranges_from_qstate(ckpt.qstate, &graph);
                for (k, v) in qat.ranges {
                    calibration.ranges.insert(k, v);
                }
            }
            let input_range = input_range_of(calib_batches);
            calib::propagate_ranges(&graph, &mut calibration, input_range);
        }

        // 4. weight quantization (at the mode's bit-width: i8 or packed i4)
        let mut qweights = std::collections::HashMap::new();
        if weight_mode.is_integer() {
            for n in graph.weight_nodes() {
                let keys: Vec<String> = match n.kind.as_str() {
                    "attention" => ["wq", "wk", "wv", "wo"]
                        .iter()
                        .map(|m| format!("{}.{m}", n.name))
                        .collect(),
                    _ => vec![format!("{}.w", n.name)],
                };
                for key in keys {
                    let Some(w) = params.get(&key) else { continue };
                    let mut qw = if range_source == RangeSource::QatScales
                        && self.accepts_qat_scales
                    {
                        // embedded QAT scales: per-channel m EMA from qstate
                        let mkey = if n.kind == "attention" {
                            format!("{key}.m")
                        } else {
                            format!("{}.m", n.name)
                        };
                        match ckpt.qstate.get(&mkey) {
                            Some(m) => {
                                // embedded stats were computed on UNfolded
                                // weights; transport through the BN fold
                                // factor |gamma|/sqrt(var+eps) per channel
                                let facs = fold_factors.get(n.name.as_str());
                                let scales: Vec<f32> = m
                                    .data
                                    .iter()
                                    .enumerate()
                                    .map(|(c, &v)| {
                                        let f = facs
                                            .map(|fv| fv[c.min(fv.len() - 1)])
                                            .unwrap_or(1.0);
                                        // same |w| statistic, landed on the
                                        // deployment's grid (127 or 7 steps)
                                        crate::tensor::weight_scale_bits(v * f, wbits)
                                    })
                                    .collect();
                                let scales = match self.weight_scheme {
                                    QuantScheme::PerChannelSym => scales,
                                    QuantScheme::PerTensorSym => {
                                        vec![scales.iter().fold(0.0f32, |a, &b| a.max(b))]
                                    }
                                };
                                QWeight::quantize_with_scales_bits(w, &scales, self.round, wbits)
                            }
                            None => QWeight::quantize_bits(w, self.weight_scheme, self.round, wbits),
                        }
                    } else {
                        QWeight::quantize_bits(w, self.weight_scheme, self.round, wbits)
                    };
                    // 5. optional AdaRound refinement on calibration data
                    // (i8 only: the greedy rounding search walks the i8 grid)
                    if ptq.adaround && wbits == 8 && !calib_batches.is_empty() && n.kind != "attention"
                    {
                        qw = adaround_refine(&graph, &params, &n.name, w, qw, calib_batches)?;
                    }
                    qweights.insert(key, qw);
                }
            }
        }

        let model = CompiledModel::new(
            graph,
            params,
            BTreeMap::new(),
            qweights,
            calibration.ranges,
            ExecConfig { weight_mode, act_mode, kernel_tier: None },
        );
        // Backends emit planned models: lowering the execution plan here
        // surfaces missing ranges/params at deploy time and lets the first
        // request run on the fast path immediately.
        model
            .plan()
            .with_context(|| format!("backend {}: execution plan lowering failed", self.name))?;
        let unsupported = self.unsupported;
        let perf_b1 = perfmodel::estimate_scaled(
            &model.graph,
            &self.device,
            precision,
            act_scaling,
            1,
            self.runtime_boost,
            &|kind| unsupported.contains(&kind),
        );
        Ok(Deployment {
            model,
            precision,
            requested,
            act_scaling,
            requested_scaling,
            backend: self.name,
            perf_b1,
        })
    }

    /// Modelled perf of this backend's compiled runtime at a precision and
    /// batch size (static activation scaling).
    pub fn perf(&self, graph: &Graph, precision: Precision, batch: usize) -> PerfReport {
        self.perf_scaled(graph, precision, ActScaling::Static, batch)
    }

    /// [`Self::perf`] with the activation-scaling axis exposed (dynamic
    /// deployments pay the per-node range-scan overhead).
    pub fn perf_scaled(
        &self,
        graph: &Graph,
        precision: Precision,
        scaling: ActScaling,
        batch: usize,
    ) -> PerfReport {
        let unsupported = self.unsupported;
        perfmodel::estimate_scaled(
            graph,
            &self.device,
            precision,
            scaling,
            batch,
            self.runtime_boost,
            &|k| unsupported.contains(&k),
        )
    }

    /// [`Self::perf_scaled`] with the static auditor's flagged layers paying
    /// the headroom mitigation term (`perfmodel::estimate_audited`). Pass
    /// `AuditReport::flagged_nodes` membership as `flagged`.
    pub fn perf_audited(
        &self,
        graph: &Graph,
        precision: Precision,
        scaling: ActScaling,
        batch: usize,
        flagged: &dyn Fn(&str) -> bool,
    ) -> PerfReport {
        let unsupported = self.unsupported;
        perfmodel::estimate_audited(
            graph,
            &self.device,
            precision,
            scaling,
            batch,
            self.runtime_boost,
            &|k| unsupported.contains(&k),
            flagged,
        )
    }

    /// Perf with naive kernel dispatch (the "CUDA" unfilled markers in Fig 3).
    pub fn perf_naive(&self, graph: &Graph, precision: Precision, batch: usize) -> PerfReport {
        let unsupported = self.unsupported;
        perfmodel::estimate(graph, &self.device, precision, batch, 1.0, &|k| {
            unsupported.contains(&k)
        })
    }
}

/// Input activation range from the calibration batches. Non-finite samples
/// (NaN/±inf from a corrupt capture) are skipped — folding them in through
/// `min`/`max` either poisons the scale or, when *every* sample is
/// non-finite, used to return the degenerate `(f32::MAX, f32::MIN)` range.
/// An empty (or all-non-finite) set falls back to the default `(-2.5, 2.5)`
/// normalized-image range.
fn input_range_of(batches: &[Tensor]) -> (f32, f32) {
    let mut lo = f32::MAX;
    let mut hi = f32::MIN;
    for b in batches {
        for &v in &b.data {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
    }
    if lo > hi {
        (-2.5, 2.5)
    } else {
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_range_skips_non_finite_samples() {
        let b = Tensor::new(vec![5], vec![f32::NAN, f32::INFINITY, -1.5, 3.0, f32::NEG_INFINITY]);
        assert_eq!(input_range_of(&[b]), (-1.5, 3.0));
    }

    #[test]
    fn input_range_degenerate_falls_back_to_default() {
        // all-non-finite calibration used to yield (f32::MAX, f32::MIN)
        let bad = Tensor::new(vec![2], vec![f32::NAN, f32::NEG_INFINITY]);
        assert_eq!(input_range_of(&[bad]), (-2.5, 2.5));
        assert_eq!(input_range_of(&[]), (-2.5, 2.5));
    }
}

/// Run the fp32 model to collect this layer's input activations, then refine
/// the rounding (calib::adaround).
fn adaround_refine(
    graph: &Graph,
    params: &BTreeMap<String, Tensor>,
    node_name: &str,
    w: &Tensor,
    qw: QWeight,
    calib_batches: &[Tensor],
) -> Result<QWeight> {
    let node = graph.node(node_name).unwrap();
    let producer = node.inputs[0].clone();
    let fp = crate::engine::fp32_model(graph.clone(), params.clone(), BTreeMap::new());
    // collect (subsampled) inputs of this node
    let mut xs: Vec<f32> = Vec::new();
    let take = |t: &Tensor, xs: &mut Vec<f32>| {
        let budget = 16_384usize.saturating_sub(xs.len());
        if budget == 0 {
            return;
        }
        let stride = (t.data.len() / budget.max(1)).max(1);
        xs.extend(t.data.iter().step_by(stride).take(budget).copied());
    };
    for b in calib_batches.iter().take(2) {
        let mut obs = |name: &str, t: &Tensor| {
            if name == producer {
                take(t, &mut xs);
            }
        };
        fp.run_observe(b, &mut obs)?;
    }
    if xs.is_empty() {
        return Ok(qw);
    }
    // adaround works on (cout, k) weight rows vs k-dim input samples; for conv
    // we approximate with channel-averaged inputs (the standard fast variant).
    let k = w.data.len() / w.shape[0];
    let samples = (xs.len() / k).max(1);
    xs.truncate(samples * k);
    if xs.len() < k {
        return Ok(qw);
    }
    Ok(crate::calib::adaround::refine_qweight(
        &Tensor::new(vec![w.shape[0], k], w.data.clone()),
        &qw,
        &xs,
        k,
    ))
}
