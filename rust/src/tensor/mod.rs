//! Minimal dense tensor types for the deployment engine.
//!
//! The engine is deliberately self-contained (no ndarray dependency — the
//! vendored crate set is fixed): `Tensor` is a shape + contiguous `Vec<f32>`,
//! `QTensor` carries quantized u8/i8 payloads with their scales.
//! Layout is row-major; images are NCHW, matching the JAX side.

pub mod quantized;

pub use quantized::{
    act_scale_zp, pack_int4, packed_row_bytes, unpack_int4, weight_qrange, weight_scale,
    weight_scale_bits, QActTensor, QWeight, QuantScheme, RoundMode,
};

/// Dense float32 tensor, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Default for Tensor {
    /// Empty tensor — the vacant state of an execution-plan buffer slot.
    fn default() -> Self {
        Tensor { shape: vec![0], data: Vec::new() }
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshaped(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape element count mismatch"
        );
        self.shape = shape.to_vec();
        self
    }

    /// 4-D accessor (NCHW).
    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        let (_, cs, hs, ws) = self.strides4();
        self.data[n * cs * self.shape[1] + c * hs * self.shape[2] + h * ws * self.shape[3] + w]
    }

    #[inline]
    fn strides4(&self) -> (usize, usize, usize, usize) {
        debug_assert_eq!(self.shape.len(), 4);
        (0, 1, 1, 1) // helper for at4 only; kept trivial
    }

    /// Max |x| over all elements.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&v| f(v)).collect() }
    }

    /// Reshape in place to `shape`, reusing both allocations, with every
    /// element reset to 0.0. Heap-traffic-free once the capacities suffice —
    /// the execution plan's steady-state buffer discipline. Use when the
    /// writer *accumulates* into the tensor.
    pub fn reset_zeroed(&mut self, shape: &[usize]) {
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        let n: usize = shape.iter().product();
        self.data.clear();
        self.data.resize(n, 0.0);
    }

    /// Reshape in place to `shape`, reusing both allocations, WITHOUT
    /// clearing element values (stale data may remain): only for writers
    /// that overwrite every element. Heap-traffic-free once warm.
    pub fn reset_for_overwrite(&mut self, shape: &[usize]) {
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        let n: usize = shape.iter().product();
        self.data.resize(n, 0.0);
    }

    /// Copy `src`'s shape and data into self, reusing allocations
    /// (heap-traffic-free once warm) — the plan executor's clone-substitute.
    pub fn copy_from(&mut self, src: &Tensor) {
        self.shape.clear();
        self.shape.extend_from_slice(&src.shape);
        self.data.clear();
        self.data.extend_from_slice(&src.data);
    }
}

/// Paper-definition empirical quantile x_(ceil(p*n)) — matches
/// `compile.kernels.ref.empirical_quantile` on the Python side.
pub fn empirical_quantile(data: &[f32], p: f64) -> f32 {
    assert!(!data.is_empty());
    let mut v: Vec<f32> = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = v.len();
    let idx = ((p * n as f64).ceil() as isize - 1).clamp(0, n as isize - 1) as usize;
    v[idx]
}

/// Strided deterministic subsample (|out| <= s_max), matching
/// `compile.kernels.ref.tensor_quantile`'s subsampling.
pub fn subsample(data: &[f32], s_max: usize) -> Vec<f32> {
    let n = data.len();
    if n <= s_max {
        return data.to_vec();
    }
    let stride = n.div_ceil(s_max);
    data.iter().step_by(stride).copied().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_matches_paper_definition() {
        // order statistics of 1..=10; p=0.5 -> x_(5) = 5
        let data: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        assert_eq!(empirical_quantile(&data, 0.5), 5.0);
        assert_eq!(empirical_quantile(&data, 0.05), 1.0);
        assert_eq!(empirical_quantile(&data, 1.0), 10.0);
        assert_eq!(empirical_quantile(&data, 0.95), 10.0);
        assert_eq!(empirical_quantile(&data, 0.91), 10.0);
        assert_eq!(empirical_quantile(&data, 0.90), 9.0);
    }

    #[test]
    fn subsample_bounds() {
        let data: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let s = subsample(&data, 100);
        assert!(s.len() <= 100);
        assert_eq!(s[0], 0.0);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::zeros(&[2, 3, 4]).reshaped(&[6, 4]);
        assert_eq!(t.shape, vec![6, 4]);
    }

    #[test]
    fn reset_helpers_reuse_capacity() {
        let mut t = Tensor::full(&[4, 4], 7.0);
        let cap = t.data.capacity();
        t.reset_zeroed(&[2, 3]);
        assert_eq!(t.shape, vec![2, 3]);
        assert!(t.data.iter().all(|&v| v == 0.0));
        assert_eq!(t.data.capacity(), cap, "shrinking reset must keep the allocation");
        t.reset_for_overwrite(&[4, 2]);
        assert_eq!((t.shape.as_slice(), t.len()), (&[4usize, 2][..], 8));
        let src = Tensor::full(&[2, 2], 1.5);
        t.copy_from(&src);
        assert_eq!(t.shape, src.shape);
        assert_eq!(t.data, src.data);
        assert_eq!(t.data.capacity(), cap, "copy_from must reuse the allocation");
    }
}
