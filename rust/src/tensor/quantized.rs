//! Quantized tensor payloads + the quantization arithmetic contract.
//!
//! The arithmetic here is THE single definition used by the integer engine
//! and all simulated backends. It mirrors `compile/kernels/ref.py`:
//! round ties-to-even, symmetric i8 weights, asymmetric u8 activations,
//! int32 accumulation. Bit-exactness against the Pallas kernels is asserted
//! by the integration tests over the exported `device_forward` HLO.
//!
//! Weights support two bit-widths (paper abstract: "symmetric/asymmetric,
//! per-tensor/per-channel, INT8/INT4"):
//! * 8-bit: one i8 per element, grid [-128, 127].
//! * 4-bit: two's-complement nibbles on the grid [-8, 7], packed two per
//!   byte **per weight row** (output channel) — rows never share a byte, so
//!   group/channel slicing stays contiguous and odd row lengths pad the
//!   final high nibble with 0. `pack_int4`/`unpack_int4` are the round-trip
//!   pair; the int4 GEMM unpacks nibbles in-register (engine/ops.rs).

use crate::tensor::Tensor;

pub const QMAX_W: f32 = 127.0;
pub const QMIN_W: f32 = -128.0;
/// 4-bit symmetric weight grid: two's-complement nibbles in [-8, 7].
pub const QMAX_W4: f32 = 7.0;
pub const QMIN_W4: f32 = -8.0;
pub const QMAX_A: f32 = 255.0;
pub const EPS: f32 = 1e-6;

/// (qmin, qmax) of the symmetric signed weight grid at a bit-width.
#[inline]
pub fn weight_qrange(bits: u8) -> (f32, f32) {
    match bits {
        4 => (QMIN_W4, QMAX_W4),
        _ => (QMIN_W, QMAX_W),
    }
}

/// Packed bytes per weight row of `per` sub-byte elements.
#[inline]
pub fn packed_row_bytes(per: usize) -> usize {
    per.div_ceil(2)
}

/// Pack rows of int4 values (each in [-8, 7], stored in i8) into
/// two-nibbles-per-byte form. Rows are packed independently: every row of
/// `per` nibbles occupies `per.div_ceil(2)` bytes, so odd `per` pads the
/// last high nibble with 0 and row slicing stays byte-aligned.
pub fn pack_int4(vals: &[i8], per: usize) -> Vec<i8> {
    if per == 0 {
        return Vec::new();
    }
    let rows = vals.len() / per;
    let bpr = packed_row_bytes(per);
    let mut out = vec![0i8; rows * bpr];
    for r in 0..rows {
        let row = &vals[r * per..(r + 1) * per];
        for (j, b) in out[r * bpr..(r + 1) * bpr].iter_mut().enumerate() {
            let lo = row[2 * j] as u8 & 0x0F;
            let hi = if 2 * j + 1 < per { (row[2 * j + 1] as u8 & 0x0F) << 4 } else { 0 };
            *b = (lo | hi) as i8;
        }
    }
    out
}

/// Inverse of [`pack_int4`]: expand packed rows back to one i8 per nibble
/// (sign-extended to [-8, 7]).
pub fn unpack_int4(packed: &[i8], rows: usize, per: usize) -> Vec<i8> {
    let bpr = packed_row_bytes(per);
    let mut out = vec![0i8; rows * per];
    for r in 0..rows {
        let row = &packed[r * bpr..(r + 1) * bpr];
        for j in 0..per {
            let b = row[j / 2];
            out[r * per + j] = if j % 2 == 0 { (b << 4) >> 4 } else { b >> 4 };
        }
    }
    out
}

/// How a backend rounds when quantizing. Vendor compilers differ; this is one
/// of the opaque degrees of freedom the paper's method is robust to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundMode {
    /// Round half to even (JAX / our reference).
    TiesEven,
    /// Round half away from zero (common in fixed-point DSP toolchains).
    HalfAway,
}

impl RoundMode {
    #[inline]
    pub fn round(self, x: f32) -> f32 {
        match self {
            RoundMode::TiesEven => x.round_ties_even(),
            RoundMode::HalfAway => x.round(),
        }
    }
}

/// Weight/activation quantization scheme knobs a vendor compiler picks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantScheme {
    /// Per-output-channel symmetric weights (best case; not all NPUs).
    PerChannelSym,
    /// Per-tensor symmetric weights (restrictive NPU compilers).
    PerTensorSym,
}

/// Quantized weight matrix/filter: integer payload + per-channel (or
/// singleton) scales along output channels. `bits` selects the storage:
/// 8-bit keeps one i8 per element; 4-bit packs two sign-extended nibbles
/// per byte, per row (see module docs).
#[derive(Clone, Debug)]
pub struct QWeight {
    pub shape: Vec<usize>,
    /// i8 payload (bits == 8) or per-row nibble-packed payload (bits == 4).
    pub data: Vec<i8>,
    /// One scale per output channel (len == shape[0]) or a single scale.
    pub scales: Vec<f32>,
    /// Per-output-channel sums of the integer payload (len == shape[0]),
    /// fixed at quantize time. This is the zero-point correction term of the
    /// integer GEMM ( sum((xq-zx)*wq) = sum(xq*wq) - zx*rowsum_w );
    /// precomputing it here means no kernel ever re-walks (or re-unpacks)
    /// the weights at run time.
    pub row_sums: Vec<i32>,
    /// Weight bit-width: 8 (i8) or 4 (packed nibbles).
    pub bits: u8,
}

impl QWeight {
    /// Assemble an 8-bit QWeight from raw parts, computing the row sums.
    pub fn from_parts(shape: Vec<usize>, data: Vec<i8>, scales: Vec<f32>) -> QWeight {
        QWeight::from_parts_bits(shape, data, scales, 8)
    }

    /// Assemble from raw *unpacked* parts at a bit-width: `data` carries one
    /// value per element regardless of `bits`; 4-bit payloads are packed
    /// here after the row sums are taken.
    pub fn from_parts_bits(shape: Vec<usize>, data: Vec<i8>, scales: Vec<f32>, bits: u8) -> QWeight {
        debug_assert!(bits == 8 || bits == 4, "unsupported weight bit-width {bits}");
        let cout = if shape.is_empty() { 1 } else { shape[0] };
        let cout = cout.max(1);
        let row_sums = row_sums_of(&data, cout);
        let data = if bits == 4 { pack_int4(&data, data.len() / cout) } else { data };
        QWeight { shape, data, scales, row_sums, bits }
    }

    /// Quantize a float weight tensor (output channels on axis 0) to i8.
    pub fn quantize(w: &Tensor, scheme: QuantScheme, round: RoundMode) -> QWeight {
        QWeight::quantize_bits(w, scheme, round, 8)
    }

    /// Quantize at a bit-width (8 or 4). The 4-bit grid is symmetric
    /// [-8, 7] with scale = absmax / 7, mirroring the i8 convention.
    pub fn quantize_bits(w: &Tensor, scheme: QuantScheme, round: RoundMode, bits: u8) -> QWeight {
        let (qmin, qmax) = weight_qrange(bits);
        let cout = if w.shape.is_empty() { 1 } else { w.shape[0] };
        let per = w.data.len() / cout.max(1);
        let scales: Vec<f32> = match scheme {
            QuantScheme::PerChannelSym => (0..cout)
                .map(|c| {
                    let s = w.data[c * per..(c + 1) * per]
                        .iter()
                        .fold(0.0f32, |m, &v| m.max(v.abs()));
                    s.max(EPS) / qmax
                })
                .collect(),
            QuantScheme::PerTensorSym => {
                vec![w.abs_max().max(EPS) / qmax]
            }
        };
        let mut data = vec![0i8; w.data.len()];
        for c in 0..cout {
            let s = scales[c.min(scales.len() - 1)];
            for i in 0..per {
                let q = round.round(w.data[c * per + i] / s).clamp(qmin, qmax);
                data[c * per + i] = q as i8;
            }
        }
        QWeight::from_parts_bits(w.shape.clone(), data, scales, bits)
    }

    /// Quantize with externally supplied scales (e.g. embedded QAT scales
    /// from the Quant-Trim checkpoint's qstate).
    pub fn quantize_with_scales(w: &Tensor, scales: &[f32], round: RoundMode) -> QWeight {
        QWeight::quantize_with_scales_bits(w, scales, round, 8)
    }

    /// Quantize with supplied scales at a bit-width.
    pub fn quantize_with_scales_bits(
        w: &Tensor,
        scales: &[f32],
        round: RoundMode,
        bits: u8,
    ) -> QWeight {
        let (qmin, qmax) = weight_qrange(bits);
        let cout = if w.shape.is_empty() { 1 } else { w.shape[0] };
        let per = w.data.len() / cout.max(1);
        let mut data = vec![0i8; w.data.len()];
        for c in 0..cout {
            let s = scales[c.min(scales.len() - 1)].max(EPS);
            for i in 0..per {
                let q = round.round(w.data[c * per + i] / s).clamp(qmin, qmax);
                data[c * per + i] = q as i8;
            }
        }
        QWeight::from_parts_bits(w.shape.clone(), data, scales.to_vec(), bits)
    }

    pub fn scale(&self, c: usize) -> f32 {
        self.scales[c.min(self.scales.len() - 1)]
    }

    /// Number of output channels (rows) of the payload.
    pub fn cout(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[0].max(1)
        }
    }

    /// Elements per output channel (nibbles, not bytes, for 4-bit payloads).
    pub fn per_row(&self) -> usize {
        let n: usize = self.shape.iter().product();
        n.max(1) / self.cout()
    }

    /// One integer value per element, whatever the storage: unpacks 4-bit
    /// payloads, copies 8-bit ones. Reference/fallback paths only — the hot
    /// kernels unpack nibbles in-register instead.
    pub fn unpacked_data(&self) -> Vec<i8> {
        if self.bits == 4 {
            unpack_int4(&self.data, self.cout(), self.per_row())
        } else {
            self.data.clone()
        }
    }

    /// Dequantize back to float (for fallback/mixed-precision paths).
    pub fn dequantize(&self) -> Tensor {
        let cout = self.cout();
        let per = self.per_row();
        let vals = self.unpacked_data();
        let mut out = vec![0.0f32; vals.len()];
        for c in 0..cout {
            let s = self.scale(c);
            for i in 0..per {
                out[c * per + i] = vals[c * per + i] as f32 * s;
            }
        }
        Tensor::new(self.shape.clone(), out)
    }
}

/// Per-output-channel i8 row sums (`cout` rows of `data.len()/cout` each).
pub fn row_sums_of(data: &[i8], cout: usize) -> Vec<i32> {
    if data.is_empty() {
        return vec![0; cout];
    }
    let per = data.len() / cout;
    (0..cout)
        .map(|c| data[c * per..(c + 1) * per].iter().map(|&w| w as i32).sum())
        .collect()
}

/// Quantized activation tensor: u8 payload + per-tensor (scale, zero point).
#[derive(Clone, Debug)]
pub struct QActTensor {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
    pub scale: f32,
    pub zero_point: i32,
}

impl QActTensor {
    /// Asymmetric per-tensor quantization given a calibrated (lo, hi) range.
    pub fn quantize(x: &Tensor, lo: f32, hi: f32, round: RoundMode) -> QActTensor {
        let (scale, zp) = act_scale_zp(lo, hi);
        let data = x
            .data
            .iter()
            .map(|&v| (round.round(v / scale) + zp as f32).clamp(0.0, QMAX_A) as u8)
            .collect();
        QActTensor { shape: x.shape.clone(), data, scale, zero_point: zp }
    }

    pub fn dequantize(&self) -> Tensor {
        let data = self
            .data
            .iter()
            .map(|&q| (q as i32 - self.zero_point) as f32 * self.scale)
            .collect();
        Tensor::new(self.shape.clone(), data)
    }
}

/// Activation scale/zero-point from a calibrated range — mirrors
/// `ref.act_scale_zp` for well-formed ranges.
///
/// A degenerate range (constant activation: `lo == hi`, or an inverted
/// pair) is widened to span zero: `[min(lo, 0), max(hi, 0)]`. The old
/// behaviour (still what the Python reference does) collapsed to an
/// EPS-wide grid, so a constant tensor at 5.0 got scale ≈ 4e-9 and a
/// clamped zero-point — the constant dequantized to ~1e-6 instead of 5.0.
/// With the widened range the constant sits on the grid exactly (q = 0 or
/// 255) and zero stays representable.
///
/// Non-degenerate ranges are passed through untouched — callers that need
/// zero in range (the engine does, for the zero-point factorization)
/// pre-widen with `lo.min(0.0)` themselves; changing that here would
/// silently shift every calibrated deployment's grid.
pub fn act_scale_zp(lo: f32, hi: f32) -> (f32, i32) {
    let (lo, hi) = if hi - lo < EPS {
        (lo.min(0.0), hi.max(0.0).max(lo + EPS))
    } else {
        (lo, hi)
    };
    let scale = (hi - lo).max(EPS) / QMAX_A;
    let zp = (-lo / scale).round_ties_even().clamp(0.0, QMAX_A) as i32;
    (scale, zp)
}

/// Weight scale from the |w| quantile EMA — mirrors `ref.weight_scale`.
pub fn weight_scale(m: f32) -> f32 {
    m.max(EPS) / QMAX_W
}

/// Bit-width-aware variant of [`weight_scale`]: the same |w| statistic
/// lands on the [-8, 7] grid when a backend deploys 4-bit weights.
pub fn weight_scale_bits(m: f32, bits: u8) -> f32 {
    let (_, qmax) = weight_qrange(bits);
    m.max(EPS) / qmax
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::new(shape.to_vec(), data)
    }

    #[test]
    fn weight_roundtrip_error_bounded_by_half_step() {
        let w = t(&[2, 3], vec![0.5, -0.25, 0.1, 1.0, -1.0, 0.75]);
        let q = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let d = q.dequantize();
        for c in 0..2 {
            let s = q.scale(c);
            for i in 0..3 {
                assert!((w.data[c * 3 + i] - d.data[c * 3 + i]).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn per_tensor_uses_single_scale() {
        let w = t(&[2, 2], vec![0.1, -0.2, 2.0, -4.0]);
        let q = QWeight::quantize(&w, QuantScheme::PerTensorSym, RoundMode::TiesEven);
        assert_eq!(q.scales.len(), 1);
        assert!((q.scales[0] - 4.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn int4_pack_unpack_roundtrip_even_and_odd() {
        for per in [1usize, 2, 3, 7, 8, 15] {
            let rows = 3;
            let vals: Vec<i8> =
                (0..rows * per).map(|i| ((i * 5 + 3) % 16) as i8 - 8).collect();
            let packed = pack_int4(&vals, per);
            assert_eq!(packed.len(), rows * packed_row_bytes(per));
            assert_eq!(unpack_int4(&packed, rows, per), vals, "per={per}");
        }
    }

    #[test]
    fn int4_all_nibble_patterns_sign_extend() {
        // every (lo, hi) nibble pair survives a pack/unpack round trip
        for lo in -8i8..=7 {
            for hi in -8i8..=7 {
                let packed = pack_int4(&[lo, hi], 2);
                assert_eq!(packed.len(), 1);
                assert_eq!(unpack_int4(&packed, 1, 2), vec![lo, hi]);
            }
        }
    }

    #[test]
    fn int4_quantize_uses_seven_step_grid() {
        let w = t(&[2, 2], vec![0.1, -0.2, 2.0, -4.0]);
        let q = QWeight::quantize_bits(&w, QuantScheme::PerTensorSym, RoundMode::TiesEven, 4);
        assert_eq!(q.bits, 4);
        assert!((q.scales[0] - 4.0 / 7.0).abs() < 1e-7);
        // packed storage: 2 nibbles per row -> 1 byte per row
        assert_eq!(q.data.len(), 2);
        let vals = q.unpacked_data();
        assert!(vals.iter().all(|&v| (-8..=7).contains(&(v as i32))));
        // roundtrip bounded by half a step
        let d = q.dequantize();
        for (a, b) in w.data.iter().zip(d.data.iter()) {
            assert!((a - b).abs() <= q.scales[0] / 2.0 + 1e-6);
        }
    }

    #[test]
    fn int4_row_sums_match_unpacked_payload() {
        let w = t(&[3, 5], (0..15).map(|i| (i as f32) * 0.3 - 2.0).collect());
        let q = QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, 4);
        let vals = q.unpacked_data();
        for c in 0..3 {
            let s: i32 = vals[c * 5..(c + 1) * 5].iter().map(|&v| v as i32).sum();
            assert_eq!(q.row_sums[c], s);
        }
    }

    #[test]
    fn act_quant_zero_point_maps_zero_exactly() {
        // zero must be representable: dequant(quant(0)) == 0 for any range
        let x = t(&[4], vec![0.0, -1.0, 2.0, 0.5]);
        let q = QActTensor::quantize(&x, -1.0, 2.0, RoundMode::TiesEven);
        let d = q.dequantize();
        assert_eq!(d.data[0], 0.0);
    }

    #[test]
    fn round_modes_differ_on_halves() {
        assert_eq!(RoundMode::TiesEven.round(2.5), 2.0);
        assert_eq!(RoundMode::HalfAway.round(2.5), 3.0);
        assert_eq!(RoundMode::TiesEven.round(3.5), 4.0);
    }

    #[test]
    fn row_sums_fixed_at_quantize_time() {
        let w = t(&[2, 3], vec![0.5, -0.25, 0.1, 1.0, -1.0, 0.75]);
        let q = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        assert_eq!(q.row_sums.len(), 2);
        for c in 0..2 {
            let s: i32 = q.data[c * 3..(c + 1) * 3].iter().map(|&v| v as i32).sum();
            assert_eq!(q.row_sums[c], s);
        }
        let q2 = QWeight::from_parts(q.shape.clone(), q.data.clone(), q.scales.clone());
        assert_eq!(q2.row_sums, q.row_sums);
    }

    #[test]
    fn scale_zp_match_python_reference() {
        // ref.act_scale_zp(lo=-1, hi=2): s = 3/255, z = round(255/3) = 85
        let (s, z) = act_scale_zp(-1.0, 2.0);
        assert!((s - 3.0 / 255.0).abs() < 1e-8);
        assert_eq!(z, 85);
    }

    #[test]
    fn degenerate_range_keeps_constant_representable() {
        // lo == hi > 0: widen to [0, hi] — the constant lands on q = 255
        let (s, z) = act_scale_zp(5.0, 5.0);
        assert!(s > 1e-3, "scale collapsed: {s}");
        assert_eq!(z, 0);
        let x = t(&[2], vec![5.0, 5.0]);
        let q = QActTensor::quantize(&x, 5.0, 5.0, RoundMode::TiesEven);
        let d = q.dequantize();
        for &v in &d.data {
            assert!((v - 5.0).abs() < 1e-4, "constant 5.0 dequantized to {v}");
        }

        // lo == hi < 0: widen to [lo, 0] — the constant lands on q = 0
        let q = QActTensor::quantize(&t(&[1], vec![-3.0]), -3.0, -3.0, RoundMode::TiesEven);
        assert!((q.dequantize().data[0] + 3.0).abs() < 1e-4);

        // lo == hi == 0: scale stays positive and zero maps to zero exactly
        let (s0, z0) = act_scale_zp(0.0, 0.0);
        assert!(s0 > 0.0 && (0..=255).contains(&z0));
        let q = QActTensor::quantize(&t(&[1], vec![0.0]), 0.0, 0.0, RoundMode::TiesEven);
        assert_eq!(q.dequantize().data[0], 0.0);
    }
}
