//! Quantized tensor payloads + the quantization arithmetic contract.
//!
//! The arithmetic here is THE single definition used by the integer engine
//! and all simulated backends. It mirrors `compile/kernels/ref.py`:
//! round ties-to-even, symmetric i8 weights, asymmetric u8 activations,
//! int32 accumulation. Bit-exactness against the Pallas kernels is asserted
//! by the integration tests over the exported `device_forward` HLO.

use crate::tensor::Tensor;

pub const QMAX_W: f32 = 127.0;
pub const QMIN_W: f32 = -128.0;
pub const QMAX_A: f32 = 255.0;
pub const EPS: f32 = 1e-6;

/// How a backend rounds when quantizing. Vendor compilers differ; this is one
/// of the opaque degrees of freedom the paper's method is robust to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundMode {
    /// Round half to even (JAX / our reference).
    TiesEven,
    /// Round half away from zero (common in fixed-point DSP toolchains).
    HalfAway,
}

impl RoundMode {
    #[inline]
    pub fn round(self, x: f32) -> f32 {
        match self {
            RoundMode::TiesEven => x.round_ties_even(),
            RoundMode::HalfAway => x.round(),
        }
    }
}

/// Weight/activation quantization scheme knobs a vendor compiler picks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantScheme {
    /// Per-output-channel symmetric weights (best case; not all NPUs).
    PerChannelSym,
    /// Per-tensor symmetric weights (restrictive NPU compilers).
    PerTensorSym,
}

/// Quantized weight matrix/filter: i8 payload + per-channel (or singleton)
/// scales along output channels.
#[derive(Clone, Debug)]
pub struct QWeight {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    /// One scale per output channel (len == shape[0]) or a single scale.
    pub scales: Vec<f32>,
    /// Per-output-channel sums of the i8 payload (len == shape[0]), fixed at
    /// quantize time. This is the zero-point correction term of the integer
    /// GEMM ( sum((xq-zx)*wq) = sum(xq*wq) - zx*rowsum_w ); precomputing it
    /// here means no kernel ever re-walks the weights at run time.
    pub row_sums: Vec<i32>,
}

impl QWeight {
    /// Assemble a QWeight from raw parts, computing the row sums.
    pub fn from_parts(shape: Vec<usize>, data: Vec<i8>, scales: Vec<f32>) -> QWeight {
        let cout = if shape.is_empty() { 1 } else { shape[0] };
        let row_sums = row_sums_of(&data, cout.max(1));
        QWeight { shape, data, scales, row_sums }
    }

    /// Quantize a float weight tensor (output channels on axis 0).
    pub fn quantize(w: &Tensor, scheme: QuantScheme, round: RoundMode) -> QWeight {
        let cout = if w.shape.is_empty() { 1 } else { w.shape[0] };
        let per = w.data.len() / cout.max(1);
        let scales: Vec<f32> = match scheme {
            QuantScheme::PerChannelSym => (0..cout)
                .map(|c| {
                    let s = w.data[c * per..(c + 1) * per]
                        .iter()
                        .fold(0.0f32, |m, &v| m.max(v.abs()));
                    s.max(EPS) / QMAX_W
                })
                .collect(),
            QuantScheme::PerTensorSym => {
                vec![w.abs_max().max(EPS) / QMAX_W]
            }
        };
        let mut data = vec![0i8; w.data.len()];
        for c in 0..cout {
            let s = scales[c.min(scales.len() - 1)];
            for i in 0..per {
                let q = round.round(w.data[c * per + i] / s).clamp(QMIN_W, QMAX_W);
                data[c * per + i] = q as i8;
            }
        }
        QWeight::from_parts(w.shape.clone(), data, scales)
    }

    /// Quantize with externally supplied scales (e.g. embedded QAT scales
    /// from the Quant-Trim checkpoint's qstate).
    pub fn quantize_with_scales(w: &Tensor, scales: &[f32], round: RoundMode) -> QWeight {
        let cout = if w.shape.is_empty() { 1 } else { w.shape[0] };
        let per = w.data.len() / cout.max(1);
        let mut data = vec![0i8; w.data.len()];
        for c in 0..cout {
            let s = scales[c.min(scales.len() - 1)].max(EPS);
            for i in 0..per {
                let q = round.round(w.data[c * per + i] / s).clamp(QMIN_W, QMAX_W);
                data[c * per + i] = q as i8;
            }
        }
        QWeight::from_parts(w.shape.clone(), data, scales.to_vec())
    }

    pub fn scale(&self, c: usize) -> f32 {
        self.scales[c.min(self.scales.len() - 1)]
    }

    /// Dequantize back to float (for fallback/mixed-precision paths).
    pub fn dequantize(&self) -> Tensor {
        let cout = if self.shape.is_empty() { 1 } else { self.shape[0] };
        let per = self.data.len() / cout.max(1);
        let mut out = vec![0.0f32; self.data.len()];
        for c in 0..cout {
            let s = self.scale(c);
            for i in 0..per {
                out[c * per + i] = self.data[c * per + i] as f32 * s;
            }
        }
        Tensor::new(self.shape.clone(), out)
    }
}

/// Per-output-channel i8 row sums (`cout` rows of `data.len()/cout` each).
pub fn row_sums_of(data: &[i8], cout: usize) -> Vec<i32> {
    if data.is_empty() {
        return vec![0; cout];
    }
    let per = data.len() / cout;
    (0..cout)
        .map(|c| data[c * per..(c + 1) * per].iter().map(|&w| w as i32).sum())
        .collect()
}

/// Quantized activation tensor: u8 payload + per-tensor (scale, zero point).
#[derive(Clone, Debug)]
pub struct QActTensor {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
    pub scale: f32,
    pub zero_point: i32,
}

impl QActTensor {
    /// Asymmetric per-tensor quantization given a calibrated (lo, hi) range.
    pub fn quantize(x: &Tensor, lo: f32, hi: f32, round: RoundMode) -> QActTensor {
        let (scale, zp) = act_scale_zp(lo, hi);
        let data = x
            .data
            .iter()
            .map(|&v| (round.round(v / scale) + zp as f32).clamp(0.0, QMAX_A) as u8)
            .collect();
        QActTensor { shape: x.shape.clone(), data, scale, zero_point: zp }
    }

    pub fn dequantize(&self) -> Tensor {
        let data = self
            .data
            .iter()
            .map(|&q| (q as i32 - self.zero_point) as f32 * self.scale)
            .collect();
        Tensor::new(self.shape.clone(), data)
    }
}

/// Activation scale/zero-point from a calibrated range — mirrors
/// `ref.act_scale_zp`.
pub fn act_scale_zp(lo: f32, hi: f32) -> (f32, i32) {
    let scale = (hi - lo).max(EPS) / QMAX_A;
    let zp = (-lo / scale).round_ties_even().clamp(0.0, QMAX_A) as i32;
    (scale, zp)
}

/// Weight scale from the |w| quantile EMA — mirrors `ref.weight_scale`.
pub fn weight_scale(m: f32) -> f32 {
    m.max(EPS) / QMAX_W
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::new(shape.to_vec(), data)
    }

    #[test]
    fn weight_roundtrip_error_bounded_by_half_step() {
        let w = t(&[2, 3], vec![0.5, -0.25, 0.1, 1.0, -1.0, 0.75]);
        let q = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let d = q.dequantize();
        for c in 0..2 {
            let s = q.scale(c);
            for i in 0..3 {
                assert!((w.data[c * 3 + i] - d.data[c * 3 + i]).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn per_tensor_uses_single_scale() {
        let w = t(&[2, 2], vec![0.1, -0.2, 2.0, -4.0]);
        let q = QWeight::quantize(&w, QuantScheme::PerTensorSym, RoundMode::TiesEven);
        assert_eq!(q.scales.len(), 1);
        assert!((q.scales[0] - 4.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn act_quant_zero_point_maps_zero_exactly() {
        // zero must be representable: dequant(quant(0)) == 0 for any range
        let x = t(&[4], vec![0.0, -1.0, 2.0, 0.5]);
        let q = QActTensor::quantize(&x, -1.0, 2.0, RoundMode::TiesEven);
        let d = q.dequantize();
        assert_eq!(d.data[0], 0.0);
    }

    #[test]
    fn round_modes_differ_on_halves() {
        assert_eq!(RoundMode::TiesEven.round(2.5), 2.0);
        assert_eq!(RoundMode::HalfAway.round(2.5), 3.0);
        assert_eq!(RoundMode::TiesEven.round(3.5), 4.0);
    }

    #[test]
    fn row_sums_fixed_at_quantize_time() {
        let w = t(&[2, 3], vec![0.5, -0.25, 0.1, 1.0, -1.0, 0.75]);
        let q = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        assert_eq!(q.row_sums.len(), 2);
        for c in 0..2 {
            let s: i32 = q.data[c * 3..(c + 1) * 3].iter().map(|&v| v as i32).sum();
            assert_eq!(q.row_sums[c], s);
        }
        let q2 = QWeight::from_parts(q.shape.clone(), q.data.clone(), q.scales.clone());
        assert_eq!(q2.row_sums, q.row_sums);
    }

    #[test]
    fn scale_zp_match_python_reference() {
        // ref.act_scale_zp(lo=-1, hi=2): s = 3/255, z = round(255/3) = 85
        let (s, z) = act_scale_zp(-1.0, 2.0);
        assert!((s - 3.0 / 255.0).abs() < 1e-8);
        assert_eq!(z, 85);
    }
}
