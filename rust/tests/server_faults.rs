//! Fault-injection integration tests for the serving path: with injected
//! worker panics, transient errors, and a sustained backend brownout the
//! server must (a) never leave a submitted request without a response,
//! (b) complete `shutdown()` with accurate stats, (c) trip the circuit
//! breaker and serve degraded traffic bit-exact with a directly-deployed
//! INT4 sibling, and (d) report a deterministic SLO-violation rate for a
//! fixed fault seed. These are the robustness contracts behind the chaos
//! scenarios in `benches/server_load.rs`.

use std::collections::BTreeMap;
use std::num::NonZeroUsize;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use quant_trim::backends::{backend_by_name, CheckpointView, PtqOptions, RangeSource};
use quant_trim::coordinator::experiment::compile_serving_fleet;
use quant_trim::coordinator::server::{
    BatchModel, BatchPolicy, BreakerPolicy, Outcome, Priority, RetryPolicy, Server, ServerConfig,
    ServerDeployment, ServerStats,
};
use quant_trim::coordinator::{Brownout, BrownoutMode, FaultPlan, FaultyModel};
use quant_trim::engine::CompiledModel;
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::{synth, Rng};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// Echoes each request's first pixel (identifies which request a response
/// answered, whatever the batch composition).
struct FirstPixel;

impl BatchModel for FirstPixel {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        let n = images.shape[0];
        let sz: usize = images.shape[1..].iter().product();
        let mut out = Tensor::zeros(&[n, 1]);
        for (i, o) in out.data.iter_mut().enumerate() {
            *o = images.data[i * sz];
        }
        Ok(out)
    }
    fn max_batch(&self) -> usize {
        8
    }
}

/// An INT8 + INT4 `hardware_d` fleet (fallbacks wired INT8 -> INT4 by the
/// fleet compiler) plus the SAME INT4 compile done directly — the oracle for
/// the bit-exact degraded-serving check.
fn int8_int4_fleet() -> (Vec<ServerDeployment>, Arc<CompiledModel>) {
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xFA17);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let fleet = compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &[
            ("hardware_d", Some(Precision::Int8), ActScaling::Static),
            ("hardware_d", Some(Precision::Int4), ActScaling::Static),
        ],
        &calib,
        4,
        None,
    )
    .unwrap();
    assert_eq!(fleet[0].name, "hardware_d@INT8");
    assert_eq!(fleet[0].fallbacks, vec!["hardware_d@INT4".to_string()]);
    let qstate = BTreeMap::new();
    let view =
        CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let direct = backend_by_name("hardware_d")
        .unwrap()
        .compile_scaled(
            view,
            Precision::Int4,
            ActScaling::Static,
            RangeSource::Calibration,
            &calib,
            PtqOptions::default(),
        )
        .expect("direct int4 sibling compile");
    (fleet, Arc::new(direct.model))
}

/// Run one image through a compiled model exactly the way the server's
/// worker does for a batch of one.
fn direct_logits(model: &CompiledModel, img: &Tensor) -> Vec<f32> {
    let mut shape = vec![1usize];
    shape.extend_from_slice(&img.shape);
    let batch = Tensor::new(shape, img.data.clone());
    let mut outs = model.run(&batch).expect("direct sibling run");
    outs.remove(0).data
}

/// (a)+(b): a panic storm (every 3rd model call panics) loses no request and
/// no stats — panicked batches are answered with error responses, each
/// panicked worker recycles itself, and `shutdown()` joins the respawned
/// generation cleanly.
#[test]
fn panic_storm_answers_every_request_and_recycles_workers() {
    let plan = FaultPlan { panic_every: NonZeroUsize::new(3), ..FaultPlan::default() };
    let server = Server::start(
        vec![ServerDeployment::new("primary", FaultyModel::new(Arc::new(FirstPixel), plan))],
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            // panics are spaced failures, not a browning-out backend: keep
            // the breaker out of this test
            breaker: BreakerPolicy { trip_after: 10_000, cooldown: Duration::from_secs(60) },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..30)
        .map(|i| (i, server.submit_image(Tensor::full(&[1, 2], i as f32), None).unwrap()))
        .collect();
    let (mut ok, mut contained) = (0usize, 0usize);
    for (i, rx) in &rxs {
        let resp = rx.recv_timeout(RECV_TIMEOUT).expect("no request may go unanswered");
        match resp.result {
            Ok(logits) => {
                assert_eq!(logits[0], *i as f32);
                assert_eq!(resp.outcome, Outcome::Served);
                ok += 1;
            }
            Err(msg) => {
                assert!(msg.contains("worker panic contained"), "{msg}");
                assert!(msg.contains("injected fault"), "{msg}");
                assert_eq!(resp.outcome, Outcome::Failed);
                contained += 1;
            }
        }
    }
    // 30 single-request batches, panic on every 3rd call: exactly 10 panics
    assert_eq!((ok, contained), (20, 10));
    let stats = server.shutdown();
    assert_eq!(stats.served, 20);
    assert_eq!(stats.errors, 10);
    assert_eq!(stats.worker_panics, 10);
    assert_eq!(stats.workers_restarted, 10, "every contained panic recycles the worker");
    assert_eq!(stats.router_panics, 0);
    assert_eq!(stats.accepted(), 30);
}

/// Transient errors are retried against the replica; once the primary trips
/// its breaker, traffic routes to the replica without burning retries.
#[test]
fn transient_errors_retry_to_replica_then_breaker_reroutes() {
    let plan = FaultPlan { transient_prob: 1.0, seed: 7, ..FaultPlan::default() };
    let flaky = ServerDeployment {
        name: "flaky".into(),
        model: Arc::new(FaultyModel::new(Arc::new(FirstPixel), plan)),
        fallbacks: vec!["replica".into()],
    };
    let replica = ServerDeployment::new("replica", FirstPixel);
    let server = Server::start(
        vec![flaky, replica],
        ServerConfig {
            workers: 1,
            queue_depth: 16,
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            breaker: BreakerPolicy { trip_after: 5, cooldown: Duration::from_secs(60) },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    // sequential submits (single worker): the breaker state each request
    // sees is exactly the previous request's outcome
    for i in 0..12u32 {
        let rx = server.submit_image(Tensor::full(&[1, 2], i as f32), Some("flaky")).unwrap();
        let resp = rx.recv_timeout(RECV_TIMEOUT).expect("answered despite the flaky primary");
        assert_eq!(resp.outcome, Outcome::Served);
        assert_eq!(resp.deployment, "replica");
        assert!(resp.degraded, "requested flaky, served by replica");
        assert_eq!(resp.result.expect("replica never fails")[0], i as f32);
        if i < 5 {
            assert_eq!(resp.retries, 1, "request {i}: one failed attempt on the primary");
        } else {
            assert_eq!(resp.retries, 0, "request {i}: breaker-open reroute, no retry burned");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 12);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.retried, 5);
    assert_eq!(stats.degraded, 12);
    assert_eq!(stats.breaker_trips, 1);
}

/// (c): a sustained brownout on the INT8 deployment trips its breaker and
/// the server serves the traffic degraded to the INT4 sibling — bit-exact
/// with the same checkpoint compiled to INT4 directly.
#[test]
fn brownout_degrades_to_int4_bit_exact_with_direct_sibling() {
    let (mut fleet, direct_int4) = int8_int4_fleet();
    let plan = FaultPlan {
        brownout: Some(Brownout { from_call: 0, calls: usize::MAX / 2, mode: BrownoutMode::Fail }),
        ..FaultPlan::default()
    };
    let primary = fleet.remove(0);
    fleet.insert(0, FaultyModel::wrap(primary, plan));
    let server = Server::start(
        fleet,
        ServerConfig {
            workers: 1,
            queue_depth: 16,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            retry: RetryPolicy { max_retries: 1, ..RetryPolicy::default() },
            breaker: BreakerPolicy { trip_after: 3, cooldown: Duration::from_secs(60) },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut rng = Rng::new(0xB17E);
    let images: Vec<Tensor> =
        (0..10).map(|_| Tensor::new(vec![3, 16, 16], rng.normal_vec(3 * 256, 1.0))).collect();
    for img in &images {
        let rx = server.submit_image(img.clone(), Some("hardware_d@INT8")).unwrap();
        let resp = rx.recv_timeout(RECV_TIMEOUT).expect("brownout traffic must still be served");
        assert_eq!(resp.outcome, Outcome::Served);
        assert_eq!(resp.deployment, "hardware_d@INT4");
        assert!(resp.degraded);
        let logits = resp.result.expect("degraded traffic serves from the INT4 sibling");
        assert_eq!(
            logits,
            direct_logits(&direct_int4, img),
            "degraded responses must be bit-exact with a directly-deployed INT4 sibling"
        );
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 10);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.degraded, 10);
    assert_eq!(stats.breaker_trips, 1);
    assert_eq!(stats.retried, 3, "only the pre-trip requests burn a retry");
    assert_eq!(stats.worker_panics, 0);
}

/// The breaker reverses: when the brownout window ends, a half-open probe
/// succeeds and traffic returns to the (un-degraded) INT8 deployment.
#[test]
fn breaker_half_open_reverts_to_primary_after_brownout() {
    let (mut fleet, _direct_int4) = int8_int4_fleet();
    let plan = FaultPlan {
        brownout: Some(Brownout { from_call: 0, calls: 5, mode: BrownoutMode::Fail }),
        ..FaultPlan::default()
    };
    let primary = fleet.remove(0);
    fleet.insert(0, FaultyModel::wrap(primary, plan));
    let cooldown = Duration::from_millis(50);
    let server = Server::start(
        fleet,
        ServerConfig {
            workers: 1,
            queue_depth: 16,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            retry: RetryPolicy { max_retries: 1, ..RetryPolicy::default() },
            breaker: BreakerPolicy { trip_after: 3, cooldown },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let img = Tensor::new(vec![3, 16, 16], Rng::new(0xB17F).normal_vec(3 * 256, 1.0));
    let ask = |tag: &str| {
        let rx = server.submit_image(img.clone(), Some("hardware_d@INT8")).unwrap();
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap_or_else(|_| panic!("no answer: {tag}"));
        assert_eq!(resp.outcome, Outcome::Served, "{tag}");
        resp
    };
    // brownout calls 0..5: three failures trip the breaker (all served
    // degraded via INT4)...
    for i in 0..3 {
        let resp = ask("pre-trip");
        assert!(resp.degraded, "request {i} must degrade during the brownout");
    }
    // ...two half-open probes still land inside the window and re-open...
    for i in 0..2 {
        std::thread::sleep(cooldown + Duration::from_millis(50));
        let resp = ask("failed probe");
        assert!(resp.degraded, "probe {i} lands in the brownout window: still degraded");
    }
    // ...the next probe lands past the window: the breaker closes and
    // traffic reverts to the primary, un-degraded
    std::thread::sleep(cooldown + Duration::from_millis(50));
    let resp = ask("recovered");
    assert_eq!(resp.deployment, "hardware_d@INT8");
    assert!(!resp.degraded, "recovered primary must serve its own traffic again");
    let resp = ask("steady state");
    assert!(!resp.degraded, "the closed breaker stays closed on success");
    let stats = server.shutdown();
    assert_eq!(stats.served, 7);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.degraded, 5);
    assert_eq!(stats.breaker_trips, 3, "initial trip + two failed half-open probes");
}

/// One seeded chaos pass: a brownout plus seed-scheduled transient errors
/// against a no-retry server, with every 4th request submitted past its
/// deadline. Deterministic by construction (single worker, sequential
/// submits, call index == request index).
fn seeded_chaos_run(seed: u64) -> ServerStats {
    let plan = FaultPlan {
        seed,
        transient_prob: 0.4,
        brownout: Some(Brownout { from_call: 0, calls: 4, mode: BrownoutMode::Fail }),
        ..FaultPlan::default()
    };
    let server = Server::start(
        vec![ServerDeployment::new("npu", FaultyModel::new(Arc::new(FirstPixel), plan))],
        ServerConfig {
            workers: 1,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            retry: RetryPolicy { max_retries: 0, ..RetryPolicy::default() },
            breaker: BreakerPolicy { trip_after: 10_000, cooldown: Duration::from_secs(60) },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    for i in 0..24 {
        // a deadline equal to the submit instant has always expired by the
        // time the router sees it: the expired subset is exact, not racy
        let deadline = (i % 4 == 3).then(Instant::now);
        let rx = server
            .submit_image_with(
                Tensor::full(&[1, 2], i as f32),
                Some("npu"),
                deadline,
                Priority::Normal,
            )
            .unwrap();
        let resp = rx.recv_timeout(RECV_TIMEOUT).expect("every chaos request is answered");
        if i % 4 == 3 {
            assert_eq!(resp.outcome, Outcome::Expired);
        }
    }
    server.shutdown()
}

/// (d): the SLO-violation rate (and every robustness counter) of a seeded
/// fault scenario replays exactly.
#[test]
fn seeded_fault_plan_yields_deterministic_violation_rate() {
    let a = seeded_chaos_run(0xD5EED);
    let b = seeded_chaos_run(0xD5EED);
    for (name, x, y) in [
        ("served", a.served, b.served),
        ("errors", a.errors, b.errors),
        ("expired", a.expired, b.expired),
        ("retried", a.retried, b.retried),
        ("degraded", a.degraded, b.degraded),
        ("breaker_trips", a.breaker_trips, b.breaker_trips),
        ("slo_misses", a.slo_misses, b.slo_misses),
        ("worker_panics", a.worker_panics, b.worker_panics),
    ] {
        assert_eq!(x, y, "{name} must replay exactly for a fixed fault seed");
    }
    assert_eq!(a.expired, 6, "every 4th of 24 requests was submitted expired");
    assert_eq!(a.accepted(), 24);
    assert!(a.errors >= 4, "the 4-call brownout window alone fails 4 requests");
    assert_eq!(a.served + a.errors, 18);
    assert_eq!(a.slo_violation_rate(), 0.25);
    assert_eq!(a.slo_violation_rate(), b.slo_violation_rate());
}

/// Satellite: deadline-triggered partial-batch flush under racing
/// submitters. `max_wait` is effectively infinite, so only the SLO lane
/// (deadline - margin) can ship these batches; 37 requests cannot partition
/// into full batches of 8, so at least one flush must be partial.
#[test]
fn slo_lane_flushes_partial_batches_under_racing_submitters() {
    let server = Server::start(
        vec![ServerDeployment::new("npu", FirstPixel)],
        ServerConfig {
            workers: 2,
            queue_depth: 256,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(600),
                slo_margin: Some(Duration::from_millis(9995)),
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let counts = [10usize, 9, 9, 9];
    let mut partial_flush = false;
    let mut served = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = counts
            .iter()
            .enumerate()
            .map(|(t, &cnt)| {
                let server = &server;
                s.spawn(move || {
                    (0..cnt)
                        .map(|i| {
                            std::thread::sleep(Duration::from_micros(500));
                            let val = (t * 100 + i) as f32;
                            // flush target = deadline - margin ~ 5ms out
                            let deadline = Instant::now() + Duration::from_secs(10);
                            let rx = server
                                .submit_image_with(
                                    Tensor::full(&[1, 2], val),
                                    None,
                                    Some(deadline),
                                    Priority::Normal,
                                )
                                .unwrap();
                            (val, rx)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (val, rx) in h.join().unwrap() {
                let resp = rx.recv_timeout(RECV_TIMEOUT).expect("SLO lane must flush batches");
                assert_eq!(resp.outcome, Outcome::Served);
                assert_eq!(resp.result.expect("echo never fails")[0], val);
                assert!((1..=8).contains(&resp.batch_size));
                partial_flush |= resp.batch_size < 8;
                served += 1;
            }
        }
    });
    assert_eq!(served, 37);
    assert!(partial_flush, "37 requests cannot partition into full 8-batches");
    let stats = server.shutdown();
    assert_eq!(stats.served, 37);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.expired, 0);
    assert_eq!(stats.slo_misses, 0, "10s deadlines with ~5ms flushes never miss");
}
