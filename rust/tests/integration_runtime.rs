//! Integration: the full AOT bridge — load exported HLO artifacts, compile on
//! the PJRT CPU client, execute train/eval/prune steps from Rust, and
//! cross-check the Rust integer engine against the JAX/Pallas device forward.
//!
//! Requires `make artifacts` to have run (skips cleanly if absent).

use std::collections::BTreeMap;
use std::path::PathBuf;

use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::{CallExtras, Curriculum, TrainConfig, Trainer, TrainState};
use quant_trim::data::{gen_cls_batch, ClsSpec};
use quant_trim::engine::fp32_model;
use quant_trim::qir::Graph;
use quant_trim::runtime::{Manifest, Runtime};
use quant_trim::tensor::Tensor;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("resnet18_c10.manifest").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn kernel_artifacts_execute_and_match_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(dir.join("kernels.manifest")).unwrap();

    // fake_quant kernel: output must be on the INT8 grid of its own scale
    let f = rt.load_fn(&man, "fake_quant").unwrap();
    let mut rng = quant_trim::testutil::Rng::new(1);
    let x = Tensor::new(vec![64, 4096], rng.normal_vec(64 * 4096, 0.7));
    let outs = f.call_tensors(&[x.clone()]).unwrap();
    let y = &outs[0];
    let s = x.abs_max() / 127.0;
    let mut max_err = 0.0f32;
    for (a, b) in x.data.iter().zip(y.data.iter()) {
        let expect = (a / s).round_ties_even().clamp(-128.0, 127.0) * s;
        max_err = max_err.max((expect - b).abs());
    }
    assert!(max_err < 1e-5, "pallas fake_quant drifted from rust ref: {max_err}");

    // qmatmul kernel vs rust integer gemm on the same quantization contract
    let f = rt.load_fn(&man, "qmatmul").unwrap();
    let a = Tensor::new(vec![256, 256], rng.normal_vec(256 * 256, 1.0));
    let w = Tensor::new(vec![256, 256], rng.normal_vec(256 * 256, 0.05));
    let outs = f.call_tensors(&[a.clone(), w.clone()]).unwrap();
    let y = &outs[0];
    // reference: sx=0.05, zx=128 (hard-coded in the artifact), sw = absmax/127
    let sw = w.abs_max().max(1e-6) / 127.0;
    let wq: Vec<i8> = w
        .data
        .iter()
        .map(|&v| (v / sw).round_ties_even().clamp(-128.0, 127.0) as i8)
        .collect();
    let mut max_rel = 0.0f32;
    for r in 0..4 {
        for c in 0..256 {
            let mut acc = 0i64;
            for k in 0..256 {
                let xq = ((a.data[r * 256 + k] / 0.05).round_ties_even() + 128.0)
                    .clamp(0.0, 255.0) as i64;
                acc += (xq - 128) * wq[k * 256 + c] as i64;
            }
            let expect = acc as f32 * 0.05 * sw;
            let got = y.data[r * 256 + c];
            let denom = expect.abs().max(1.0);
            max_rel = max_rel.max((expect - got).abs() / denom);
        }
    }
    assert!(max_rel < 1e-4, "pallas qmatmul vs rust int gemm: rel err {max_rel}");
}

#[test]
fn train_step_runs_and_learns_fixed_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(dir.join("resnet18_c10.manifest")).unwrap();
    let cfg = TrainConfig::quant_trim(1, 1, Curriculum::cifar());
    let mut tr = Trainer::new(&rt, man, cfg).unwrap();
    let bs = tr.batch_size();
    let batch = gen_cls_batch(ClsSpec::cifar10(), bs, 7);
    let (l0, _) = tr.train_step(&batch, 0.0, 3e-4).unwrap();
    let mut last = l0;
    for _ in 0..8 {
        let (l, _) = tr.train_step(&batch, 0.0, 3e-4).unwrap();
        last = l;
    }
    assert!(last < l0 * 0.8, "loss should drop on a fixed batch: {l0} -> {last}");
    assert!(tr.state.step > 8.0);
}

#[test]
fn reverse_prune_clips_weight_tails() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(dir.join("resnet18_c10.manifest")).unwrap();
    let cfg = TrainConfig::quant_trim(1, 1, Curriculum::cifar());
    let mut tr = Trainer::new(&rt, man, cfg).unwrap();
    let before: f32 = tr.state.params["s0.b0.c1.w"].abs_max();
    tr.reverse_prune("reverse_prune_90").unwrap();
    let w = &tr.state.params["s0.b0.c1.w"];
    let after = w.abs_max();
    let tau = tr.state.qstate["s0.b0.c1.tau"].data[0];
    assert!(after <= tau + 1e-6, "weights must be pinned at tau: {after} vs {tau}");
    assert!(after < before, "tails should be clipped: {before} -> {after}");
}

#[test]
fn rust_engine_matches_pjrt_fp32_forward() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(dir.join("resnet18_c10.manifest")).unwrap();
    let graph = Graph::load(dir.join("resnet18_c10.qir")).unwrap();
    let ck = Checkpoint::load(dir.join("resnet18_c10.init.qtckpt")).unwrap();
    let state = TrainState::from_checkpoint(&ck);

    let spec = man.fns["forward"].clone();
    let batch_size = spec.args.iter().find(|s| s.role == "data").unwrap().shape[0];
    let batch = gen_cls_batch(ClsSpec::cifar10(), batch_size, 99);

    // PJRT forward
    let f = rt.load_fn(&man, "forward").unwrap();
    let extras = CallExtras { data: Some(&batch.images), ..Default::default() };
    let args = state.marshal(&spec, &extras).unwrap();
    let outs = f.call(&args).unwrap();
    let jax_logits =
        quant_trim::runtime::literal_to_tensor(&outs[0], &spec.rets[0].shape).unwrap();

    // Rust engine forward
    let params: BTreeMap<String, Tensor> =
        state.params.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    let bn: BTreeMap<String, Tensor> =
        state.bn.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    let model = fp32_model(graph, params, bn);
    let rust_logits = model.run(&batch.images).unwrap().remove(0);

    assert_eq!(jax_logits.shape, rust_logits.shape);
    let scale = jax_logits.abs_max().max(1.0);
    let mut max_err = 0.0f32;
    for (a, b) in jax_logits.data.iter().zip(rust_logits.data.iter()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < scale * 2e-3,
        "rust fp32 engine vs PJRT forward: max err {max_err} (scale {scale})"
    );
}

#[test]
fn rust_engine_matches_pjrt_forward_all_model_families() {
    // Exercises every engine op: attention/layernorm/to_tokens/tokmean (vit),
    // depthwise conv + SE + hswish (mobilenet), concat/upsample (unet),
    // residual adds (resnet). Gold standard: the PJRT-executed JAX forward.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    for model in ["resnet18_c10", "vit", "mobilenetv3", "unet"] {
        let man = Manifest::load(dir.join(format!("{model}.manifest"))).unwrap();
        let graph = Graph::load(dir.join(format!("{model}.qir"))).unwrap();
        let ck = Checkpoint::load(dir.join(format!("{model}.init.qtckpt"))).unwrap();
        let state = TrainState::from_checkpoint(&ck);
        let spec = man.fns["forward_b1"].clone();
        // random input in the image shape
        let ishape = &spec.args.iter().find(|s| s.role == "data").unwrap().shape;
        let mut rng = quant_trim::testutil::Rng::new(0xF0_0D + model.len() as u64);
        let n: usize = ishape.iter().product();
        let x = Tensor::new(ishape.clone(), rng.normal_vec(n, 1.0));

        let f = rt.load_fn(&man, "forward_b1").unwrap();
        let extras = CallExtras { data: Some(&x), ..Default::default() };
        let args = state.marshal(&spec, &extras).unwrap();
        let outs = f.call(&args).unwrap();
        let jax_out =
            quant_trim::runtime::literal_to_tensor(&outs[0], &spec.rets[0].shape).unwrap();

        let model_rs = fp32_model(graph, state.params.clone(), state.bn.clone());
        let rust_out = model_rs.run(&x).unwrap().remove(0);
        let rust_out = rust_out.reshaped(&jax_out.shape.clone());
        let scale = jax_out.abs_max().max(1.0);
        let mut max_err = 0.0f32;
        for (a, b) in jax_out.data.iter().zip(rust_out.data.iter()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < scale * 5e-3,
            "{model}: rust engine vs PJRT forward max err {max_err} (scale {scale})"
        );
    }
}
