//! Regression: the plan-based executor must reproduce the legacy
//! interpreter on every `ExecConfig` — F32/Bf16/F16/Int8/DynInt8
//! activations × F32/Int8/Int4 weights — on a ResNet-style conv net and a
//! ViT-style transformer graph. The integer paths (i8 and nibble-packed i4,
//! static and dynamic activation scaling) are asserted BIT-EXACT (equality,
//! not tolerance); the float paths keep the reference kernels' accumulation
//! order and are asserted exact-within-1e-6 relative. DynInt8 models are
//! built with EMPTY `act_ranges` — the dynamic path must need no
//! calibration at all.

use std::collections::{BTreeMap, HashMap};

use quant_trim::backends::{backend_by_name, CheckpointView, PtqOptions, RangeSource};
use quant_trim::calib::{calibrate, CalibMethod};
use quant_trim::engine::{fp32_model, ActMode, CompiledModel, ExecConfig, ExecScratch, WeightMode};
use quant_trim::perfmodel::Precision;
use quant_trim::qir::passes;
use quant_trim::tensor::{QWeight, QuantScheme, RoundMode, Tensor};
use quant_trim::testutil::synth::{self, SynthModel};
use quant_trim::testutil::Rng;

/// Quantize every weight-bearing node of a graph at a weight bit-width.
fn quantize_weights(
    graph: &quant_trim::qir::Graph,
    params: &BTreeMap<String, Tensor>,
    scheme: QuantScheme,
    round: RoundMode,
    bits: u8,
) -> HashMap<String, QWeight> {
    let mut q = HashMap::new();
    for n in graph.weight_nodes() {
        let keys: Vec<String> = match n.kind.as_str() {
            "attention" => ["wq", "wk", "wv", "wo"].iter().map(|m| format!("{}.{m}", n.name)).collect(),
            _ => vec![format!("{}.w", n.name)],
        };
        for key in keys {
            if let Some(w) = params.get(&key) {
                q.insert(key, QWeight::quantize_bits(w, scheme, round, bits));
            }
        }
    }
    q
}

/// Calibrated ranges for every node (MinMax over a couple of batches).
fn ranges_for(
    graph: &quant_trim::qir::Graph,
    params: &BTreeMap<String, Tensor>,
    batches: &[Tensor],
) -> HashMap<String, (f32, f32)> {
    let fp = fp32_model(graph.clone(), params.clone(), BTreeMap::new());
    calibrate(&fp, batches, CalibMethod::MinMax).unwrap().ranges
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    let scale = a.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
    a.iter().zip(b.iter()).fold(0.0f32, |m, (x, y)| m.max((x - y).abs())) / scale
}

/// Run the full ExecConfig matrix on one lowered graph and compare the
/// planned executor against the interpreter.
fn check_matrix(sm: &SynthModel, input_shape: &[usize], label: &str) {
    // lower like a vendor backend: fold BN + fuse activations
    let (graph, params, _factors, _fused) =
        passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    let n: usize = input_shape.iter().product();
    let mut rng = Rng::new(0xE8A7);
    let batches: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(input_shape.to_vec(), rng.normal_vec(n, 1.0))).collect();
    let ranges = ranges_for(&graph, &params, &batches);
    let q_perchan = quantize_weights(&graph, &params, QuantScheme::PerChannelSym, RoundMode::TiesEven, 8);
    let q4_perchan = quantize_weights(&graph, &params, QuantScheme::PerChannelSym, RoundMode::TiesEven, 4);
    let x = Tensor::new(input_shape.to_vec(), rng.normal_vec(n, 1.0));

    let act_modes = [
        ActMode::F32,
        ActMode::Bf16,
        ActMode::F16,
        ActMode::Int8 { round: RoundMode::TiesEven },
        ActMode::DynInt8 { round: RoundMode::TiesEven },
    ];
    for weight_mode in [WeightMode::F32, WeightMode::Int8, WeightMode::Int4] {
        // the qweights a backend would ship for this mode: 4-bit packed
        // payloads under Int4, i8 otherwise
        let qweights = if weight_mode == WeightMode::Int4 { &q4_perchan } else { &q_perchan };
        for act_mode in act_modes {
            let cfg = ExecConfig { weight_mode, act_mode, kernel_tier: None };
            // dynamic scaling is calibration-free by contract: build those
            // models with NO act_ranges at all
            let cfg_ranges = if act_mode.is_dynamic() { HashMap::new() } else { ranges.clone() };
            let model = CompiledModel::new(
                graph.clone(),
                params.clone(),
                BTreeMap::new(),
                qweights.clone(),
                cfg_ranges,
                cfg,
            );
            let interp = model.run_interpreted(&x).unwrap();
            let planned = model.run(&x).unwrap();
            assert_eq!(interp.len(), planned.len());
            for (a, b) in interp.iter().zip(planned.iter()) {
                assert_eq!(a.shape, b.shape, "{label} {cfg:?}: shape mismatch");
                if weight_mode.is_integer() && act_mode.is_integer() {
                    // the integer engine (static or dynamic activation
                    // scaling): bit-exact, asserted as equality
                    assert_eq!(
                        a.data, b.data,
                        "{label} {cfg:?}: planned integer executor must be bit-exact"
                    );
                } else {
                    let err = max_rel_err(&a.data, &b.data);
                    assert!(err <= 1e-6, "{label} {cfg:?}: plan drifted, rel err {err}");
                }
            }
        }
    }

    // restrictive-NPU flavor: per-tensor weights + DSP rounding, integer
    // path at both weight bit-widths, static AND dynamic scaling
    for bits in [8u8, 4] {
        for act_mode in [
            ActMode::Int8 { round: RoundMode::HalfAway },
            ActMode::DynInt8 { round: RoundMode::HalfAway },
        ] {
            let q_pertensor = quantize_weights(
                &graph,
                &params,
                QuantScheme::PerTensorSym,
                RoundMode::HalfAway,
                bits,
            );
            let weight_mode = if bits == 4 { WeightMode::Int4 } else { WeightMode::Int8 };
            let cfg = ExecConfig { weight_mode, act_mode, kernel_tier: None };
            let cfg_ranges = if act_mode.is_dynamic() { HashMap::new() } else { ranges.clone() };
            let model = CompiledModel::new(
                graph.clone(),
                params.clone(),
                BTreeMap::new(),
                q_pertensor,
                cfg_ranges,
                cfg,
            );
            let interp = model.run_interpreted(&x).unwrap();
            let planned = model.run(&x).unwrap();
            for (a, b) in interp.iter().zip(planned.iter()) {
                assert_eq!(
                    a.data, b.data,
                    "{label}: per-tensor/half-away int{bits} {act_mode:?} must be bit-exact"
                );
            }
        }
    }
}

#[test]
fn plan_matches_interpreter_resnet_style() {
    check_matrix(&synth::resnet_like(16, 16), &[2, 3, 16, 16], "resnet-like");
}

#[test]
fn plan_matches_interpreter_vit_style() {
    check_matrix(&synth::vit_like(), &[2, 3, 8, 8], "vit-like");
}

#[test]
fn plan_matches_interpreter_on_unfused_graph_with_bn() {
    // the raw (un-lowered) graph still carries bn nodes and standalone
    // activations: the plan must execute those identically too
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xBE);
    let x = Tensor::new(vec![1, 3, 16, 16], rng.normal_vec(3 * 256, 1.0));
    let model = fp32_model(sm.graph.clone(), sm.params.clone(), sm.bn.clone());
    let interp = model.run_interpreted(&x).unwrap();
    let planned = model.run(&x).unwrap();
    for (a, b) in interp.iter().zip(planned.iter()) {
        assert_eq!(a.data, b.data, "fp32 unfused graph: plan must match interpreter exactly");
    }
}

#[test]
fn plan_reuses_buffers_and_moves_passthroughs() {
    let sm = synth::resnet_like(16, 16);
    let (graph, params, _f, fused) = passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    assert!(fused >= 3, "stem relu, dw hswish and SE hsigmoid should fuse, got {fused}");
    let model = fp32_model(graph, params, BTreeMap::new());
    let plan = model.plan().unwrap();
    assert!(
        plan.slot_count() < plan.node_count(),
        "liveness plan should reuse buffers: {} slots for {} nodes",
        plan.slot_count(),
        plan.node_count()
    );
}

#[test]
fn backend_compiled_deployment_is_plan_backed_and_bit_exact() {
    // end-to-end through a vendor backend: hardware_d INT8 on the synthetic
    // checkpoint; the deployment's run() (planned) must equal the
    // interpreter bit-for-bit
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xD0);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let qstate = BTreeMap::new();
    let view = CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let be = backend_by_name("hardware_d").unwrap();
    let dep = be
        .compile(view, Precision::Int8, RangeSource::Calibration, &calib, PtqOptions::default())
        .unwrap();
    let x = Tensor::new(vec![1, 3, 16, 16], rng.normal_vec(3 * 256, 1.0));
    let planned = dep.model.run(&x).unwrap();
    let interp = dep.model.run_interpreted(&x).unwrap();
    assert_eq!(planned[0].data, interp[0].data, "deployed int8 plan must be bit-exact");
    assert!(planned[0].data.iter().all(|v| v.is_finite()));
}

#[test]
fn backend_compiled_int4_deployment_is_bit_exact_and_engages_the_4bit_grid() {
    // hardware_d has native int4 kernels: a Precision::Int4 request must
    // produce a genuine W4/A8 deployment (no fallback), bit-exact between
    // plan and interpreter, with logits that differ from the W8/A8
    // deployment of the same checkpoint (the coarser grid is really in use)
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xD4);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let qstate = BTreeMap::new();
    let be = backend_by_name("hardware_d").unwrap();
    let compile_at = |p: Precision| {
        let view =
            CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
        be.compile(view, p, RangeSource::Calibration, &calib, PtqOptions::default()).unwrap()
    };
    let dep4 = compile_at(Precision::Int4);
    assert_eq!(dep4.precision, Precision::Int4);
    assert!(!dep4.fell_back());
    assert!(dep4.model.qweights.values().all(|q| q.bits == 4), "int4 deployment ships packed weights");
    let x = Tensor::new(vec![1, 3, 16, 16], rng.normal_vec(3 * 256, 1.0));
    let planned = dep4.model.run(&x).unwrap();
    let interp = dep4.model.run_interpreted(&x).unwrap();
    assert_eq!(planned[0].data, interp[0].data, "deployed int4 plan must be bit-exact");
    assert!(planned[0].data.iter().all(|v| v.is_finite()));

    let dep8 = compile_at(Precision::Int8);
    let y8 = dep8.model.run(&x).unwrap();
    assert_ne!(planned[0].data, y8.first().unwrap().data, "int4 grid must actually differ from int8");
}

#[test]
fn int4_request_falls_back_to_int8_without_subbyte_kernels() {
    // rk3588 has no int4 MAC arrays: the request compiles, but as the INT8
    // engine — and says so on the deployment
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xD5);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let qstate = BTreeMap::new();
    let be = backend_by_name("rk3588").unwrap();
    let view = CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let dep = be
        .compile(view, Precision::Int4, RangeSource::Calibration, &calib, PtqOptions::default())
        .unwrap();
    assert_eq!(dep.requested, Precision::Int4);
    assert_eq!(dep.precision, Precision::Int8);
    assert!(dep.fell_back());
    assert!(dep.model.qweights.values().all(|q| q.bits == 8), "fallback ships plain i8 weights");
    let x = Tensor::new(vec![1, 3, 16, 16], rng.normal_vec(3 * 256, 1.0));
    // the fallback deployment IS the int8 deployment, bit for bit
    let view = CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let dep8 = be
        .compile(view, Precision::Int8, RangeSource::Calibration, &calib, PtqOptions::default())
        .unwrap();
    assert_eq!(dep.model.run(&x).unwrap()[0].data, dep8.model.run(&x).unwrap()[0].data);
}

#[test]
fn dyn_int8_runs_bit_exact_without_any_act_ranges() {
    // the acceptance contract of the dynamic path: no act_ranges, no
    // calibration — and still bit-exact between plan and interpreter, with
    // logits that really come from live ranges (≠ the calibrated grid)
    let sm = synth::resnet_like(16, 16);
    let (graph, params, _f, _fused) =
        passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    let mut rng = Rng::new(0xDA11);
    let x = Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0));
    let qweights =
        quantize_weights(&graph, &params, QuantScheme::PerChannelSym, RoundMode::TiesEven, 8);
    let dyn_model = CompiledModel::new(
        graph.clone(),
        params.clone(),
        BTreeMap::new(),
        qweights.clone(),
        HashMap::new(), // calibration-free
        ExecConfig {
            weight_mode: WeightMode::Int8,
            act_mode: ActMode::DynInt8 { round: RoundMode::TiesEven },
            kernel_tier: None,
        },
    );
    let planned = dyn_model.run(&x).unwrap();
    let interp = dyn_model.run_interpreted(&x).unwrap();
    assert_eq!(planned[0].data, interp[0].data, "dynamic int8 plan must be bit-exact");
    assert!(planned[0].data.iter().all(|v| v.is_finite()));

    // same weights under STATIC calibrated ranges: a different grid
    let batches: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let static_model = CompiledModel::new(
        graph,
        params,
        BTreeMap::new(),
        qweights,
        ranges_for(&dyn_model.graph, &dyn_model.params, &batches),
        ExecConfig {
            weight_mode: WeightMode::Int8,
            act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
            kernel_tier: None,
        },
    );
    let y_static = static_model.run(&x).unwrap();
    assert_ne!(
        planned[0].data, y_static[0].data,
        "dynamic ranges must actually differ from the calibrated static grid"
    );
}

#[test]
fn scratch_reuse_across_runs_batches_and_models_is_bit_exact() {
    // ONE ExecScratch serves: repeated runs, changing batch sizes (grow,
    // shrink, regrow), and a different deployment (int4) — every planned
    // result must still equal the interpreter bit for bit; arena reuse
    // must never leak state between inferences
    let sm = synth::resnet_like(16, 16);
    let (graph, params, _f, _fused) =
        passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    let mut rng = Rng::new(0x5C8A);
    let batches: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let ranges = ranges_for(&graph, &params, &batches);
    let model_at = |bits: u8| {
        let weight_mode = if bits == 4 { WeightMode::Int4 } else { WeightMode::Int8 };
        CompiledModel::new(
            graph.clone(),
            params.clone(),
            BTreeMap::new(),
            quantize_weights(&graph, &params, QuantScheme::PerChannelSym, RoundMode::TiesEven, bits),
            ranges.clone(),
            ExecConfig {
                weight_mode,
                act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
                kernel_tier: None,
            },
        )
    };
    let m8 = model_at(8);
    let m4 = model_at(4);
    let mut scratch = ExecScratch::new();
    for &bsz in &[2usize, 1, 3, 2] {
        let x = Tensor::new(vec![bsz, 3, 16, 16], rng.normal_vec(bsz * 3 * 256, 1.0));
        for m in [&m8, &m4] {
            let interp = m.run_interpreted(&x).unwrap();
            let planned = m.run_with(&x, &mut scratch).unwrap();
            assert_eq!(planned.len(), interp.len());
            assert_eq!(planned[0].shape, interp[0].shape, "b={bsz}");
            assert_eq!(
                planned[0].data, interp[0].data,
                "scratch reuse broke bit-exactness at b={bsz}"
            );
        }
    }
}

#[test]
fn unfusing_backend_still_matches() {
    // rk3588 does not fuse activations: its deployments carry standalone
    // act nodes; plan and interpreter must still agree exactly on int8
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xD1);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let qstate = BTreeMap::new();
    let view = CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let be = backend_by_name("rk3588").unwrap();
    let dep = be
        .compile(view, Precision::Int8, RangeSource::Calibration, &calib, PtqOptions::default())
        .unwrap();
    // the activations were NOT fused away
    assert!(dep.model.graph.node("r1").is_some(), "rk3588 keeps standalone activations");
    let x = Tensor::new(vec![1, 3, 16, 16], rng.normal_vec(3 * 256, 1.0));
    assert_eq!(dep.model.run(&x).unwrap()[0].data, dep.model.run_interpreted(&x).unwrap()[0].data);
}
