//! Deterministic loopback integration suite for the cluster tier
//! (`coordinator::cluster`): membership lifecycle on a mock clock, live
//! registration/heartbeat/eviction over ephemeral `127.0.0.1` ports,
//! bit-exact replica failover (static-precision siblings, independently
//! compiled), node leave *mid-traffic* with zero lost accepted requests,
//! drain-on-shutdown across the whole cluster, the >=3x 1->4 node
//! throughput-scaling assertion behind `benches/cluster_load.rs`, and the
//! `/metrics`-vs-`ServerStats` counter-export regression over the PR 6
//! seeded chaos replay.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;
use quant_trim::coordinator::cluster::{
    infer, scrape_metrics, ClusterNode, Membership, NodeConfig, Router, RouterConfig,
};
use quant_trim::coordinator::experiment::{compile_serving_fleet, place_fleet_on_nodes};
use quant_trim::coordinator::server::{
    BatchModel, BatchPolicy, BreakerPolicy, RetryPolicy, ServerConfig, ServerDeployment,
};
use quant_trim::coordinator::{Brownout, BrownoutMode, FaultPlan, FaultyModel};
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::{synth, Rng};

const CALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Echoes each request's first pixel (identifies which request a response
/// answered, whatever the routing path).
struct FirstPixel;

impl BatchModel for FirstPixel {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        let n = images.shape[0];
        let sz: usize = images.shape[1..].iter().product();
        let mut out = Tensor::zeros(&[n, 1]);
        for (i, o) in out.data.iter_mut().enumerate() {
            *o = images.data[i * sz];
        }
        Ok(out)
    }
    fn max_batch(&self) -> usize {
        8
    }
}

/// FirstPixel paced by a fixed per-batch sleep: service time dominates host
/// jitter, so wall-clock scaling assertions are robust.
struct PacedEcho {
    delay: Duration,
}

impl BatchModel for PacedEcho {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        FirstPixel.run_batch(images)
    }
    fn max_batch(&self) -> usize {
        1
    }
}

/// Node config for echo-serving tests: strict one-request batches on one
/// worker (a node's throughput is then exactly 1/delay), fast heartbeats.
fn echo_node_config() -> NodeConfig {
    NodeConfig {
        server: ServerConfig {
            workers: 1,
            queue_depth: 256,
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
        heartbeat_every: Duration::from_millis(40),
        ..NodeConfig::default()
    }
}

fn echo_deployment(delay_ms: u64) -> Vec<ServerDeployment> {
    vec![ServerDeployment::new("echo", PacedEcho { delay: Duration::from_millis(delay_ms) })]
}

/// Poll until `cond` holds (or a generous deadline passes) — used only for
/// liveness transitions (registration arriving over HTTP), never for
/// correctness values.
fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------------
// Membership lifecycle on a mock clock (zero sleeps, zero sockets)
// ---------------------------------------------------------------------------

#[test]
fn membership_lifecycle_with_mock_clock() {
    let t0 = Instant::now();
    let t = |ms: u64| t0 + Duration::from_millis(ms);
    let addr = |port: u16| format!("127.0.0.1:{port}").parse().unwrap();
    let timeout = Duration::from_millis(300);
    let mut m = Membership::new(128);

    // register -> member; re-register refreshes, not duplicates
    assert!(m.register("n0", addr(7001), ["echo".to_string()], t(0)));
    assert!(m.register("n1", addr(7002), ["echo".to_string()], t(0)));
    assert!(!m.register("n0", addr(7001), ["echo".to_string()], t(50)));
    assert_eq!(m.len(), 2);

    // heartbeats hold eviction off exactly while they keep arriving
    for beat_ms in [100u64, 200, 300, 400] {
        assert!(m.heartbeat("n1", t(beat_ms)));
    }
    assert!(m.evict_stale(timeout, t(340)).is_empty(), "n0 beat at 50 is 290ms old: inside 300");
    let evicted = m.evict_stale(timeout, t(360));
    assert_eq!(evicted, vec!["n0".to_string()], "n0's beat is now 310ms old");
    assert!(!m.contains("n0") && m.contains("n1"));

    // an evicted node cannot heartbeat back in; it must re-register
    assert!(!m.heartbeat("n0", t(400)));
    assert!(m.register("n0", addr(7001), ["echo".to_string()], t(400)));
    assert!(m.heartbeat("n1", t(600)), "keep n1 fresh for the boundary check below");

    // exactly-at-timeout is NOT stale (strict >): deterministic boundary
    assert!(m.evict_stale(timeout, t(700)).is_empty(), "n0 is exactly 300ms old at 700");
    assert_eq!(m.evict_stale(timeout, t(701)), vec!["n0".to_string()]);

    // voluntary leave drops ring membership immediately
    assert!(m.leave("n1"));
    assert!(!m.leave("n1"), "second leave is a no-op");
    assert!(m.is_empty());

    // placement follows membership: no members, no replicas
    assert!(m.replicas_for("k", Some("echo"), 2).is_empty());
}

// ---------------------------------------------------------------------------
// Live registration -> heartbeat -> eviction over loopback HTTP
// ---------------------------------------------------------------------------

#[test]
fn live_registration_heartbeat_and_eviction() {
    let router = Router::start(RouterConfig {
        heartbeat_timeout: Duration::from_millis(250),
        sweep_every: Duration::from_millis(25),
        ..RouterConfig::default()
    })
    .unwrap();

    // a real node registers itself and stays alive through heartbeats
    let node =
        ClusterNode::start("live-n0", echo_deployment(1), echo_node_config(), Some(router.addr()))
            .unwrap();
    wait_for("node registration", || router.members() == 1);
    let epoch_after_join = router.epoch();

    // a phantom admitted directly and never heartbeating gets evicted
    router.admit("ghost", "127.0.0.1:9".parse().unwrap(), &["echo".to_string()]);
    wait_for("ghost eviction", || router.members() == 1 && router.stats().evicted >= 1);
    assert!(router.epoch() > epoch_after_join, "eviction bumps the membership epoch");

    // the heartbeating node survived the entire ghost lifetime
    std::thread::sleep(Duration::from_millis(400));
    assert_eq!(router.members(), 1, "heartbeats must keep the live node in");

    // graceful shutdown deregisters via /leave
    node.shutdown();
    wait_for("node leave", || router.members() == 0);
    let stats = router.shutdown();
    assert!(stats.left >= 1, "shutdown must deregister through /leave");
    assert!(stats.heartbeats > 0, "the node heartbeated while alive");
    assert!(stats.evicted >= 1, "the ghost was evicted by timeout");
}

// ---------------------------------------------------------------------------
// Routing and failover
// ---------------------------------------------------------------------------

/// Requests routed through the router come back with the echo payload, the
/// serving node's identity, and spread across nodes by key — and the same
/// key always lands on the same node.
#[test]
fn router_spreads_keys_and_serves_exact_echoes() {
    let router = Router::start(RouterConfig::default()).unwrap();
    let nodes: Vec<ClusterNode> = (0..3)
        .map(|i| {
            ClusterNode::start(
                format!("spread-n{i}"),
                echo_deployment(1),
                echo_node_config(),
                Some(router.addr()),
            )
            .unwrap()
        })
        .collect();
    wait_for("3 registrations", || router.members() == 3);

    let mut by_node: BTreeMap<String, usize> = BTreeMap::new();
    let mut owner_of_key0 = String::new();
    for i in 0..48 {
        let image = Tensor::full(&[1, 2], i as f32);
        let reply = infer(
            router.addr(),
            Some("echo"),
            Some(&format!("spread-key-{i}")),
            &image,
            None,
            CALL_TIMEOUT,
        )
        .unwrap();
        assert!(reply.is_served(), "request {i}: {:?}", reply.error);
        assert_eq!(reply.logits.as_ref().unwrap().data, vec![i as f32], "echo must match");
        assert_eq!(reply.failovers, 0, "healthy cluster needs no failover");
        let node = reply.node.unwrap();
        if i == 0 {
            owner_of_key0 = node.clone();
        }
        *by_node.entry(node).or_insert(0) += 1;
    }
    assert_eq!(by_node.len(), 3, "48 keys at 128 vnodes reach all 3 nodes: {by_node:?}");

    // placement is deterministic: re-sending a key hits the same node
    let again = infer(
        router.addr(),
        Some("echo"),
        Some("spread-key-0"),
        &Tensor::full(&[1, 2], 0.0),
        None,
        CALL_TIMEOUT,
    )
    .unwrap();
    assert_eq!(again.node.unwrap(), owner_of_key0);

    for node in nodes {
        node.shutdown();
    }
    router.shutdown();
}

/// Replica failover is bit-exact for static-precision siblings: the same
/// checkpoint compiled twice (independently) on two nodes must serve
/// identical logits before and after the primary leaves.
#[test]
fn replica_failover_is_bit_exact_for_static_siblings() {
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xFA17);
    let calib: Vec<Tensor> = (0..2)
        .map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0)))
        .collect();
    let compile = || {
        compile_serving_fleet(
            &sm.graph,
            &sm.params,
            &sm.bn,
            &[("hardware_d", Some(Precision::Int8), ActScaling::Static)],
            &calib,
            4,
            None,
        )
        .unwrap()
    };

    let router = Router::start(RouterConfig::default()).unwrap();
    // two INDEPENDENT compiles of the same checkpoint: the bit-exactness of
    // failover rests on deterministic compilation, not on a shared Arc
    let mut nodes: Vec<ClusterNode> = ["exact-a", "exact-b"]
        .into_iter()
        .map(|id| {
            ClusterNode::start(id, compile(), NodeConfig::default(), Some(router.addr())).unwrap()
        })
        .collect();
    wait_for("2 registrations", || router.members() == 2);

    let image = Tensor::new(vec![3, 16, 16], rng.normal_vec(3 * 256, 1.0));
    let key = Some("exactness-key");
    let first = infer(router.addr(), Some("hardware_d"), key, &image, None, CALL_TIMEOUT).unwrap();
    assert!(first.is_served(), "{:?}", first.error);
    assert_eq!(first.failovers, 0);
    let primary = first.node.clone().unwrap();

    // drop the node that served; the replica must answer, bit-exact
    let leaver_idx = nodes.iter().position(|n| n.id() == primary).expect("primary is a node");
    let leaver = nodes.remove(leaver_idx);
    leaver.shutdown();
    wait_for("primary left", || router.members() == 1);
    let survivor_id = nodes[0].id().to_string();

    let second = infer(router.addr(), Some("hardware_d"), key, &image, None, CALL_TIMEOUT).unwrap();
    assert!(second.is_served(), "{:?}", second.error);
    assert_eq!(second.node.as_deref(), Some(survivor_id.as_str()), "replica must take over");
    assert_eq!(
        first.logits.as_ref().unwrap().data,
        second.logits.as_ref().unwrap().data,
        "failover must be bit-exact for static-precision siblings"
    );

    for node in nodes {
        node.shutdown();
    }
    router.shutdown();
}

/// ACCEPTANCE: a node leaving mid-traffic loses zero accepted requests —
/// every request of a concurrent client barrage is answered 200 with the
/// right payload while one of three nodes drains and leaves.
#[test]
fn node_leave_mid_traffic_loses_zero_accepted_requests() {
    let router = Router::start(RouterConfig::default()).unwrap();
    let mut nodes: Vec<ClusterNode> = (0..3)
        .map(|i| {
            ClusterNode::start(
                format!("drain-n{i}"),
                echo_deployment(2),
                echo_node_config(),
                Some(router.addr()),
            )
            .unwrap()
        })
        .collect();
    wait_for("3 registrations", || router.members() == 3);

    const THREADS: usize = 8;
    const PER_THREAD: usize = 24;
    let answered = AtomicUsize::new(0);
    let leaver_served = AtomicUsize::new(0);
    let router_addr = router.addr();
    std::thread::scope(|scope| {
        let answered = &answered;
        for t in 0..THREADS {
            scope.spawn(move || {
                for j in 0..PER_THREAD {
                    let i = t * PER_THREAD + j;
                    let image = Tensor::full(&[1, 2], i as f32);
                    let reply = infer(
                        router_addr,
                        Some("echo"),
                        Some(&format!("drain-key-{i}")),
                        &image,
                        None,
                        CALL_TIMEOUT,
                    )
                    .expect("transport to the router must hold");
                    assert_eq!(
                        reply.status, 200,
                        "request {i} lost during the leave: {:?}",
                        reply.error
                    );
                    assert_eq!(reply.logits.as_ref().unwrap().data, vec![i as f32]);
                    answered.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // mid-barrage: gracefully remove one node (deregister, drain, close)
        while answered.load(Ordering::Relaxed) < THREADS * PER_THREAD / 4 {
            std::thread::sleep(Duration::from_millis(2));
        }
        let leaver = nodes.remove(1);
        let left_stats = leaver.shutdown();
        // drain contract: nothing the leaver accepted errored or expired
        assert_eq!(left_stats.errors, 0, "drained node failed accepted requests");
        assert_eq!(left_stats.expired, 0);
        leaver_served.store(left_stats.served, Ordering::Relaxed);
    });
    assert_eq!(answered.load(Ordering::Relaxed), THREADS * PER_THREAD, "every request answered");

    let rstats = router.stats();
    assert_eq!(rstats.no_replica, 0, "replication must always offer a live replica");
    assert_eq!(rstats.forwarded_ok, THREADS * PER_THREAD);

    let mut total_served = leaver_served.load(Ordering::Relaxed);
    for node in nodes {
        total_served += node.shutdown().served;
    }
    // every answer was executed exactly once, except the rare failover that
    // re-executes on a replica after the first node already served it
    assert!(
        total_served >= THREADS * PER_THREAD
            && total_served <= THREADS * PER_THREAD + rstats.failovers,
        "served {total_served} across nodes for {} requests ({} failovers)",
        THREADS * PER_THREAD,
        rstats.failovers
    );
    router.shutdown();
}

// ---------------------------------------------------------------------------
// Throughput scaling (the bench's acceptance assertion, in-suite)
// ---------------------------------------------------------------------------

/// Drive `total` sleep-paced requests through a fresh n-node cluster with one
/// concurrent client thread per request; returns (elapsed, per-node counts).
fn run_scaling_round(
    n_nodes: usize,
    total: usize,
    delay_ms: u64,
) -> (Duration, BTreeMap<String, usize>) {
    let router = Router::start(RouterConfig::default()).unwrap();
    let nodes: Vec<ClusterNode> = (0..n_nodes)
        .map(|i| {
            ClusterNode::start(
                format!("scale-n{i}"),
                echo_deployment(delay_ms),
                echo_node_config(),
                Some(router.addr()),
            )
            .unwrap()
        })
        .collect();
    wait_for("registrations", || router.members() == n_nodes);

    let router_addr = router.addr();
    let by_node: Mutex<BTreeMap<String, usize>> = Mutex::new(BTreeMap::new());
    let started = Instant::now();
    std::thread::scope(|scope| {
        let by_node = &by_node;
        // one thread per request: every node's backlog is fully submitted up
        // front, so wall-clock = the busiest node's serial service time
        for i in 0..total {
            scope.spawn(move || {
                let image = Tensor::full(&[1, 2], i as f32);
                let reply = infer(
                    router_addr,
                    Some("echo"),
                    Some(&format!("load-key-{i}")),
                    &image,
                    None,
                    CALL_TIMEOUT,
                )
                .expect("loopback transport");
                assert!(reply.is_served(), "request {i}: {:?}", reply.error);
                assert_eq!(reply.logits.as_ref().unwrap().data, vec![i as f32]);
                *by_node.lock().unwrap().entry(reply.node.unwrap()).or_insert(0) += 1;
            });
        }
    });
    let elapsed = started.elapsed();
    for node in nodes {
        node.shutdown();
    }
    router.shutdown();
    (elapsed, by_node.into_inner().unwrap())
}

/// ACCEPTANCE: aggregate throughput scales >=3x from 1 to 4 router-attached
/// nodes. Service time is sleep-paced (8ms per request, one worker per
/// node), so the wall-clock ratio is pinned by placement, not host speed: at
/// 128 vnodes the busiest of 4 nodes owns 26/96 of these keys (deterministic
/// hash), bounding the ideal ratio at 96/26 = 3.69.
#[test]
fn throughput_scales_3x_from_1_to_4_nodes() {
    const TOTAL: usize = 96;
    const DELAY_MS: u64 = 8;
    let (t1, shares1) = run_scaling_round(1, TOTAL, DELAY_MS);
    let (t4, shares4) = run_scaling_round(4, TOTAL, DELAY_MS);

    assert_eq!(shares1.values().sum::<usize>(), TOTAL);
    assert_eq!(shares4.values().sum::<usize>(), TOTAL);
    assert_eq!(shares1.len(), 1);
    assert_eq!(shares4.len(), 4, "all 4 nodes must take load: {shares4:?}");
    // structural half of the assertion: deterministic placement keeps the
    // busiest node at <= 30/96 of the keys (actual: 26)
    let busiest = *shares4.values().max().unwrap();
    assert!(busiest <= 30, "placement skew too high: {shares4:?}");

    // wall-clock half: >=3x aggregate throughput going 1 -> 4 nodes
    let speedup = t1.as_secs_f64() / t4.as_secs_f64();
    assert!(
        speedup >= 3.0,
        "1->4 node speedup {speedup:.2} < 3.0 (t1={t1:?}, t4={t4:?}, shares {shares4:?})"
    );
}

// ---------------------------------------------------------------------------
// Cluster-wide drain
// ---------------------------------------------------------------------------

/// Shutting the whole cluster down loses nothing: node drains answer every
/// accepted request, and the per-node stats sum to the traffic sent.
#[test]
fn cluster_wide_drain_accounts_for_every_request() {
    let router = Router::start(RouterConfig::default()).unwrap();
    let nodes: Vec<ClusterNode> = (0..2)
        .map(|i| {
            ClusterNode::start(
                format!("shut-n{i}"),
                echo_deployment(1),
                echo_node_config(),
                Some(router.addr()),
            )
            .unwrap()
        })
        .collect();
    wait_for("2 registrations", || router.members() == 2);

    const N: usize = 20;
    for i in 0..N {
        let reply = infer(
            router.addr(),
            Some("echo"),
            Some(&format!("shut-key-{i}")),
            &Tensor::full(&[1, 2], i as f32),
            None,
            CALL_TIMEOUT,
        )
        .unwrap();
        assert!(reply.is_served());
    }

    let mut served = 0usize;
    for node in nodes {
        let stats = node.shutdown();
        assert_eq!(stats.errors, 0, "echo deployments never fail");
        assert_eq!(stats.expired, 0, "no deadlines were set");
        served += stats.served;
    }
    assert_eq!(served, N, "cluster drain must account for every request");
    let rstats = router.shutdown();
    assert_eq!(rstats.forwarded_ok, N);
    assert_eq!(rstats.no_replica, 0);
}

// ---------------------------------------------------------------------------
// /metrics export regression (satellite: dropped-counter class of bug)
// ---------------------------------------------------------------------------

/// Drive the PR 6 seeded chaos scenario through a live node's HTTP front
/// door: a brownout + seed-scheduled transient errors on a no-retry server,
/// every 4th request pre-expired. Returns the node for scraping.
fn seeded_chaos_node(seed: u64) -> ClusterNode {
    let plan = FaultPlan {
        seed,
        transient_prob: 0.4,
        brownout: Some(Brownout { from_call: 0, calls: 4, mode: BrownoutMode::Fail }),
        ..FaultPlan::default()
    };
    let node = ClusterNode::start(
        format!("chaos-{seed:x}"),
        vec![ServerDeployment::new("npu", FaultyModel::new(Arc::new(FirstPixel), plan))],
        NodeConfig {
            server: ServerConfig {
                workers: 1,
                queue_depth: 64,
                policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                    slo_margin: None,
                },
                retry: RetryPolicy { max_retries: 0, ..RetryPolicy::default() },
                breaker: BreakerPolicy { trip_after: 10_000, cooldown: Duration::from_secs(60) },
                ..ServerConfig::default()
            },
            ..NodeConfig::default()
        },
        None,
    )
    .unwrap();
    // sequential client, single worker, 1-request batches: the fault
    // schedule (call index == non-expired request index) replays exactly
    for i in 0..24u32 {
        let image = Tensor::full(&[1, 2], i as f32);
        // a 0ms deadline has always expired by the time the batcher sees it
        let deadline_ms = (i % 4 == 3).then_some(0);
        let reply = infer(node.addr(), Some("npu"), None, &image, deadline_ms, CALL_TIMEOUT)
            .expect("node transport");
        if i % 4 == 3 {
            assert_eq!(reply.status, 504, "pre-expired requests answer 504 Gateway Timeout");
        } else {
            assert!(
                reply.status == 200 || reply.status == 502,
                "chaos requests are served or failed, got {} ({:?})",
                reply.status,
                reply.error
            );
        }
    }
    node
}

/// ACCEPTANCE (satellite): `/metrics` agrees exactly with the in-process
/// `ServerStats` after a seeded chaos run — every exported counter, not a
/// subset. The exhaustive destructuring in `ServerStats::export` makes a
/// *new* field unforgettable at compile time; this test pins the runtime
/// path (render -> HTTP -> parse) to the in-process values.
#[test]
fn metrics_endpoint_agrees_exactly_with_server_stats_after_chaos() {
    let node = seeded_chaos_node(0xC4A05);
    let snapshot = node.stats_snapshot().expect("node is live");
    let scraped = scrape_metrics(node.addr(), CALL_TIMEOUT).unwrap();

    let export = snapshot.export();
    assert_eq!(
        scraped.len(),
        export.len(),
        "/metrics must carry every exported stat: {scraped:?}"
    );
    for (name, value) in &export {
        let key = format!("pallas_{name}");
        let scraped_value = scraped
            .get(&key)
            .unwrap_or_else(|| panic!("counter {key} dropped from /metrics: {scraped:?}"));
        if *name == "throughput_rps" {
            // the only wall-clock-denominated stat: scrape and snapshot see
            // different elapsed times, so only finiteness is comparable
            assert!(scraped_value.is_finite());
        } else {
            assert_eq!(
                scraped_value, value,
                "counter {key}: /metrics says {scraped_value}, in-process says {value}"
            );
        }
    }

    // chaos shape is pinned by the seed: exactly 6 pre-expired requests,
    // and every request accounted for
    assert_eq!(snapshot.expired, 6);
    assert_eq!(snapshot.accepted(), 24);
    assert!(snapshot.errors > 0, "the brownout must have failed some calls");

    // quiescent server: the final drain sees the same counters
    let fin = node.shutdown();
    assert_eq!(fin.served, snapshot.served);
    assert_eq!(fin.errors, snapshot.errors);
    assert_eq!(fin.expired, snapshot.expired);
    assert_eq!(fin.worker_panics, snapshot.worker_panics);
    assert_eq!(fin.slo_misses, snapshot.slo_misses);
    assert_eq!(fin.p95_ms, snapshot.p95_ms, "percentiles come from the same reservoir");
}

/// The same chaos seed replays to identical counters on a fresh node — the
/// `/metrics` regression above is anchored to a deterministic scenario.
#[test]
fn chaos_replay_is_deterministic_across_nodes() {
    let a = seeded_chaos_node(0x2EBA);
    let b = seeded_chaos_node(0x2EBA);
    let (sa, sb) = (a.stats_snapshot().unwrap(), b.stats_snapshot().unwrap());
    assert_eq!(sa.served, sb.served);
    assert_eq!(sa.errors, sb.errors);
    assert_eq!(sa.expired, sb.expired);
    assert_eq!(sa.retried, sb.retried);
    assert_eq!(sa.degraded, sb.degraded);
    assert_eq!(sa.breaker_trips, sb.breaker_trips);
    a.shutdown();
    b.shutdown();
}

// ---------------------------------------------------------------------------
// Fleet placement
// ---------------------------------------------------------------------------

/// `place_fleet_on_nodes` puts every deployment on exactly R nodes, prunes
/// fallbacks to co-located siblings, and places deterministically.
#[test]
fn fleet_placement_replicates_and_prunes_fallbacks() {
    let fleet = vec![
        ServerDeployment::new("m-int8", FirstPixel).with_fallbacks(vec!["m-int4".to_string()]),
        ServerDeployment::new("m-int4", FirstPixel),
        ServerDeployment::new("other", FirstPixel),
    ];
    let node_ids: Vec<String> = (0..4).map(|i| format!("place-n{i}")).collect();
    let shards = place_fleet_on_nodes(&fleet, &node_ids, 2).unwrap();
    assert_eq!(shards.len(), 4);
    for name in ["m-int8", "m-int4", "other"] {
        let copies: usize =
            shards.iter().map(|s| s.iter().filter(|d| d.name == name).count()).sum();
        assert_eq!(copies, 2, "{name} must live on exactly R=2 nodes");
    }
    for (shard, id) in shards.iter().zip(&node_ids) {
        let local: Vec<&str> = shard.iter().map(|d| d.name.as_str()).collect();
        for dep in shard {
            for fb in &dep.fallbacks {
                assert!(
                    local.contains(&fb.as_str()),
                    "node {id}: fallback {fb} of {} is not co-located",
                    dep.name
                );
            }
        }
    }
    // determinism: a second placement is identical
    let again = place_fleet_on_nodes(&fleet, &node_ids, 2).unwrap();
    for (a, b) in shards.iter().zip(&again) {
        let an: Vec<&str> = a.iter().map(|d| d.name.as_str()).collect();
        let bn: Vec<&str> = b.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(an, bn);
    }
    // replication above the node count degrades to all nodes
    let all = place_fleet_on_nodes(&fleet, &node_ids, 10).unwrap();
    let copies: usize = all.iter().map(|s| s.iter().filter(|d| d.name == "other").count()).sum();
    assert_eq!(copies, 4);
    // a non-empty placed shard boots: the pruned fallbacks pass the server's
    // co-location validation
    let shard = again.into_iter().find(|s| !s.is_empty()).expect("some node hosts something");
    ClusterNode::start("place-boot", shard, NodeConfig::default(), None).unwrap().shutdown();
}
