//! Adversarial HTTP/1.1 parser tests for `coordinator::wire` — at the parser
//! level (seeded byte-mangling corpus, split/partial reads) and against a
//! live loopback `ClusterNode` (malformed request lines, oversized headers,
//! premature disconnects, pipelined requests). Contract: every input yields
//! a 400/431/413 answer or a clean close — never a panic and never a hung
//! connection. Fully deterministic: loopback only, seeded corpus, EOF-driven
//! closes (no timing races).

use std::io::{BufReader, Cursor, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use anyhow::Result;
use quant_trim::coordinator::cluster::{ClusterNode, NodeConfig};
use quant_trim::coordinator::server::{
    BatchModel, BatchPolicy, ServerConfig, ServerDeployment,
};
use quant_trim::coordinator::wire::{
    decode_tensor, encode_tensor, read_http_response, read_request, HttpRequest, WireError,
    MAX_HEADERS, MAX_HEADER_LINE, MAX_REQUEST_LINE,
};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::Rng;

/// Echoes each request's first pixel.
struct FirstPixel;

impl BatchModel for FirstPixel {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        let n = images.shape[0];
        let sz: usize = images.shape[1..].iter().product();
        let mut out = Tensor::zeros(&[n, 1]);
        for (i, o) in out.data.iter_mut().enumerate() {
            *o = images.data[i * sz];
        }
        Ok(out)
    }
    fn max_batch(&self) -> usize {
        8
    }
}

fn parse(bytes: &[u8]) -> Result<Option<HttpRequest>, WireError> {
    read_request(&mut Cursor::new(bytes.to_vec()))
}

/// A small corpus of well-formed requests the mangler starts from.
fn valid_corpus() -> Vec<Vec<u8>> {
    let tensor = encode_tensor(&Tensor::full(&[1, 2], 7.0));
    let mut infer = format!(
        "POST /infer?deployment=echo&key=k1 HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        tensor.len()
    )
    .into_bytes();
    infer.extend_from_slice(&tensor);
    vec![
        b"GET /healthz HTTP/1.1\r\n\r\n".to_vec(),
        b"GET /state HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n".to_vec(),
        b"GET /metrics HTTP/1.1\r\nAccept: text/plain\r\n\r\n".to_vec(),
        b"POST /heartbeat?id=n0 HTTP/1.1\r\nContent-Length: 0\r\n\r\n".to_vec(),
        infer,
    ]
}

/// Seeded mangles: truncate, bit-flip, byte insert, byte zero, slice swap.
fn mangle(rng: &mut Rng, base: &[u8]) -> Vec<u8> {
    let mut bytes = base.to_vec();
    match rng.below(5) {
        0 => {
            let at = rng.below(bytes.len().max(1));
            bytes.truncate(at);
        }
        1 => {
            let at = rng.below(bytes.len().max(1));
            if at < bytes.len() {
                bytes[at] ^= 1 << rng.below(8);
            }
        }
        2 => {
            let at = rng.below(bytes.len() + 1);
            bytes.insert(at, (rng.below(256)) as u8);
        }
        3 => {
            let at = rng.below(bytes.len().max(1));
            if at < bytes.len() {
                bytes[at] = 0;
            }
        }
        _ => {
            if bytes.len() >= 4 {
                let a = rng.below(bytes.len() - 1);
                let b = rng.below(bytes.len() - 1);
                bytes.swap(a, b);
            }
        }
    }
    bytes
}

// ---------------------------------------------------------------------------
// Parser-level properties (no sockets)
// ---------------------------------------------------------------------------

/// Hand-picked malformed request lines all answer 400.
#[test]
fn malformed_request_lines_are_400() {
    let cases: &[&[u8]] = &[
        b"\r\n\r\n",                                  // empty request line
        b"GET\r\n\r\n",                               // no target
        b"GET /x\r\n\r\n",                            // no version
        b"GET  /x HTTP/1.1\r\n\r\n",                  // double space
        b"GET /x HTTP/1.1 extra\r\n\r\n",             // trailing token
        b"G@T /x HTTP/1.1\r\n\r\n",                   // bad method token
        b"GET x HTTP/1.1\r\n\r\n",                    // not origin-form
        b"GET /x HTTP/2.0\r\n\r\n",                   // unsupported version
        b"GET /x HTTP/1.1\r\nNoColonHere\r\n\r\n",    // header without colon
        b"GET /x HTTP/1.1\r\nBad Name: v\r\n\r\n",    // space in header name
        b"GET /x HTTP/1.1\r\n: empty-name\r\n\r\n",   // empty header name
        b"POST /x HTTP/1.1\r\nContent-Length: -1\r\n\r\n", // bad length
        b"POST /x HTTP/1.1\r\nContent-Length: 99999999999999999999\r\n\r\n",
        b"GET /x HT",                                 // truncated request line
        b"GET /x HTTP/1.1\r\nHost: x",                // truncated headers
        b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc", // truncated body
    ];
    for case in cases {
        let err = parse(case).expect_err(&format!("{:?} must not parse", String::from_utf8_lossy(case)));
        assert_eq!(err.status(), 400, "{}", err);
    }
}

/// Oversized inputs answer 431 (request line / header line / header count).
#[test]
fn oversized_inputs_are_431() {
    let long_line = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_REQUEST_LINE + 1));
    assert_eq!(parse(long_line.as_bytes()).unwrap_err().status(), 431);
    let long_header = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "b".repeat(MAX_HEADER_LINE + 1));
    assert_eq!(parse(long_header.as_bytes()).unwrap_err().status(), 431);
    let many: String = (0..=MAX_HEADERS).map(|i| format!("X-{i}: v\r\n")).collect();
    assert_eq!(parse(format!("GET / HTTP/1.1\r\n{many}\r\n").as_bytes()).unwrap_err().status(), 431);
}

/// The seeded byte-mangling corpus: every mangled request either parses or
/// yields a typed error with a sane status — the parser is total and never
/// panics. 600 cases across 3 seeds, fully deterministic.
#[test]
fn mangled_corpus_never_panics_and_errors_are_typed() {
    let corpus = valid_corpus();
    for seed in [0xF00Du64, 0xBEEF, 0x5EED] {
        let mut rng = Rng::new(seed);
        for i in 0..200 {
            let base = &corpus[rng.below(corpus.len())];
            let mut mangled = base.clone();
            // stack 1..=3 mangles for deeper corruption
            for _ in 0..(1 + rng.below(3)) {
                mangled = mangle(&mut rng, &mangled);
            }
            match parse(&mangled) {
                Ok(_) => {} // still (or again) well-formed — fine
                Err(e) => {
                    assert!(
                        matches!(e.status(), 400 | 413 | 431),
                        "seed {seed} case {i}: unexpected status {} for {e}",
                        e.status()
                    );
                }
            }
        }
    }
}

/// A reader that drips bytes in seeded small chunks: split/partial reads
/// must parse identically to a whole-buffer read.
struct DripReader {
    data: Vec<u8>,
    at: usize,
    sizes: Vec<usize>,
    step: usize,
}

impl Read for DripReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.at >= self.data.len() {
            return Ok(0);
        }
        let want = self.sizes[self.step % self.sizes.len()].max(1);
        self.step += 1;
        let n = want.min(buf.len()).min(self.data.len() - self.at);
        buf[..n].copy_from_slice(&self.data[self.at..self.at + n]);
        self.at += n;
        Ok(n)
    }
}

#[test]
fn split_reads_parse_identically_to_whole_buffer() {
    let corpus = valid_corpus();
    let mut rng = Rng::new(0xD41);
    for base in &corpus {
        let whole = parse(base).unwrap().expect("corpus entry is valid");
        for _ in 0..8 {
            let sizes: Vec<usize> = (0..8).map(|_| 1 + rng.below(7)).collect();
            let drip = DripReader { data: base.clone(), at: 0, sizes, step: 0 };
            // tiny BufReader capacity worsens the splitting further
            let mut r = BufReader::with_capacity(3, drip);
            let req = read_request(&mut r).unwrap().expect("split read must still parse");
            assert_eq!(req.method, whole.method);
            assert_eq!(req.path, whole.path);
            assert_eq!(req.query_pairs, whole.query_pairs);
            assert_eq!(req.headers, whole.headers);
            assert_eq!(req.body, whole.body);
        }
    }
}

// ---------------------------------------------------------------------------
// Live loopback node: adversarial clients against the real front door
// ---------------------------------------------------------------------------

fn echo_node() -> ClusterNode {
    ClusterNode::start(
        "adversarial-target",
        vec![ServerDeployment::new("echo", FirstPixel)],
        NodeConfig {
            server: ServerConfig {
                workers: 1,
                queue_depth: 32,
                policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                    slo_margin: None,
                },
                ..ServerConfig::default()
            },
            request_timeout: Duration::from_secs(10),
            // bounds how long a silent peer can hold a handler
            read_timeout: Duration::from_millis(300),
            ..NodeConfig::default()
        },
        None,
    )
    .expect("start adversarial target node")
}

/// Send raw bytes, half-close the write side (deterministic EOF at the
/// server), and read whatever comes back until the server closes.
fn raw_exchange(addr: std::net::SocketAddr, bytes: &[u8]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    stream.write_all(bytes).expect("send");
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut out = Vec::new();
    let _ = stream.read_to_end(&mut out); // server-side close ends this
    out
}

fn status_of(raw: &[u8]) -> Option<u16> {
    let text = String::from_utf8_lossy(raw);
    let mut parts = text.split(' ');
    match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code.parse().ok(),
        _ => None,
    }
}

fn assert_healthy(node: &ClusterNode) {
    let raw = raw_exchange(node.addr(), b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status_of(&raw), Some(200), "node must stay healthy");
}

#[test]
fn live_node_answers_malformed_lines_with_400_and_survives() {
    let node = echo_node();
    for case in
        [&b"BAD\r\n\r\n"[..], b"GET x HTTP/1.1\r\n\r\n", b"GET /x HTTP/9.9\r\n\r\n", b"\x00\x01\x02\x03"]
    {
        let raw = raw_exchange(node.addr(), case);
        assert_eq!(status_of(&raw), Some(400), "case {:?}", String::from_utf8_lossy(case));
    }
    assert_healthy(&node);
    node.shutdown();
}

#[test]
fn live_node_answers_oversized_headers_with_431_and_survives() {
    let node = echo_node();
    let big = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "x".repeat(MAX_HEADER_LINE + 100));
    let raw = raw_exchange(node.addr(), big.as_bytes());
    assert_eq!(status_of(&raw), Some(431));
    assert_healthy(&node);
    node.shutdown();
}

#[test]
fn premature_disconnects_never_wedge_the_node() {
    let node = echo_node();
    let cuts: &[&[u8]] = &[
        b"",                                        // connect + immediate close
        b"GET /hea",                                // mid request line
        b"GET /healthz HTTP/1.1\r\nHost: ",         // mid header
        b"POST /infer HTTP/1.1\r\nContent-Length: 500\r\n\r\nshort", // mid body
    ];
    for cut in cuts {
        let raw = raw_exchange(node.addr(), cut);
        // empty cut = clean EOF (no response); the rest are truncations (400)
        if cut.is_empty() {
            assert!(raw.is_empty(), "clean EOF deserves no response bytes");
        } else {
            assert_eq!(status_of(&raw), Some(400), "cut {:?}", String::from_utf8_lossy(cut));
        }
        assert_healthy(&node);
    }
    node.shutdown();
}

/// A silent open connection is dropped at the read timeout — the handler is
/// not held forever, and the node keeps serving others meanwhile.
#[test]
fn silent_connections_time_out_without_blocking_service() {
    let node = echo_node();
    let idle = TcpStream::connect(node.addr()).expect("connect");
    // while the silent peer idles, service continues
    assert_healthy(&node);
    // after the 300ms read timeout the server closes the silent connection
    std::thread::sleep(Duration::from_millis(600));
    assert_healthy(&node);
    drop(idle);
    node.shutdown();
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let node = echo_node();
    let mut stream = TcpStream::connect(node.addr()).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    stream
        .write_all(
            b"GET /healthz HTTP/1.1\r\n\r\nGET /state HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n",
        )
        .expect("pipeline 3 requests");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let a = read_http_response(&mut reader).expect("first pipelined response");
    let b = read_http_response(&mut reader).expect("second pipelined response");
    let c = read_http_response(&mut reader).expect("third pipelined response");
    assert_eq!((a.status, b.status, c.status), (200, 200, 200));
    assert_eq!(a.text(), "ok");
    assert!(b.text().contains("\"deployments\""), "state body: {}", b.text());
    // after Connection: close the server must close — EOF, not a hang
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).expect("clean close after Connection: close");
    assert!(rest.is_empty());
    node.shutdown();
}

/// The live-socket version of the mangling corpus: every mangled blob gets a
/// typed response or a clean close, and the node never stops serving. The
/// half-close after each blob makes server-side EOF (not timeouts) drive
/// every case — deterministic and fast.
#[test]
fn live_mangled_corpus_gets_typed_answers_and_node_survives() {
    let node = echo_node();
    let corpus = valid_corpus();
    let mut rng = Rng::new(0xC0FFEE);
    for i in 0..60 {
        let base = &corpus[rng.below(corpus.len())];
        let mangled = mangle(&mut rng, base);
        let raw = raw_exchange(node.addr(), &mangled);
        // a blob without a parseable status means the server closed without
        // answering (clean-EOF case) — legal; reaching this line at all
        // proves the connection was closed rather than hung
        if let Some(status) = status_of(&raw) {
            assert!(
                matches!(status, 200 | 400 | 404 | 405 | 413 | 429 | 431 | 500 | 502 | 503 | 504),
                "case {i}: unexpected status {status}"
            );
        }
    }
    assert_healthy(&node);
    let stats = node.shutdown();
    // the adversarial barrage must not have crashed any server thread
    assert_eq!((stats.worker_panics, stats.router_panics), (0, 0));
}

/// Tensor codec adversarial cases: truncations and mangles of a valid body
/// must error (or decode), never panic — and the error path is the node's
/// 400 on /infer.
#[test]
fn tensor_codec_is_total_under_mangling() {
    let valid = encode_tensor(&Tensor::new(vec![2, 3], vec![0.5; 6]));
    for cut in 0..valid.len() {
        let _ = decode_tensor(&valid[..cut]); // must not panic; mostly errors
    }
    let mut rng = Rng::new(0xDEC0DE);
    for _ in 0..200 {
        let mangled = mangle(&mut rng, &valid);
        let _ = decode_tensor(&mangled); // total: Ok or Err, never a panic
    }
    // live: a garbage /infer body answers 400 and the node survives
    let node = echo_node();
    let body = b"not-a-tensor";
    let req = format!(
        "POST /infer?deployment=echo HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let mut blob = req.into_bytes();
    blob.extend_from_slice(body);
    let raw = raw_exchange(node.addr(), &blob);
    assert_eq!(status_of(&raw), Some(400));
    assert_healthy(&node);
    node.shutdown();
}
