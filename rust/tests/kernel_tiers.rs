//! Kernel-tier equivalence suite (`engine::simd`): the SIMD tiers must be
//! BIT-IDENTICAL to the scalar tier, not merely close.
//!
//! * property sweep over random and adversarial (rows, K, dout) shapes —
//!   K under / at / over the 16-element vector step, K % 16 != 0 tails,
//!   odd INT4 column counts (low-nibble tail) — asserting the packed
//!   integer linear kernel produces the same bits on both tiers at both
//!   weight bit-widths;
//! * exact i32 accumulator recovery: with `sxw = 1`, `zx = 128` and K
//!   small enough that `|acc| < 2^24`, the f32 output IS the corrected
//!   accumulator, so the kernels are checked against an i64 brute-force
//!   reference — any lost or duplicated lane/tail term is caught exactly;
//! * f32 panel kernels: same `[k][4]` panel layout on every tier, same
//!   mul-then-add sequence per lane, bit-identical outputs;
//! * full planned deployments: a scalar-forced `ExecConfig` twin matches
//!   the detected tier bitwise at INT8 and INT4, and `ExecPlan` reports
//!   the tier it resolved.
//!
//! On a machine whose detected tier IS the scalar tier the comparisons are
//! trivially true; the CI `kernel-matrix` job runs this suite on an
//! AVX2-capable runner where they are not.

use std::collections::{BTreeMap, HashMap};

use quant_trim::calib::{calibrate, CalibMethod};
use quant_trim::engine::{
    fp32_model, ops, ActMode, CompiledModel, ExecConfig, KernelTier, WeightMode,
};
use quant_trim::qir::passes;
use quant_trim::tensor::{QWeight, QuantScheme, RoundMode, Tensor};
use quant_trim::testutil::{synth, Rng};

/// Shapes chosen to hit every tail path of the 16-wide integer kernels:
/// below / at / above one vector step, K % 16 != 0, and odd K (the INT4
/// packed low-nibble tail).
const SHAPES: [(usize, usize, usize); 10] = [
    (1, 1, 1),
    (2, 7, 3),
    (3, 15, 5),
    (1, 16, 4),
    (2, 17, 6),
    (4, 31, 9),
    (2, 33, 8),
    (5, 64, 16),
    (3, 100, 11),
    (2, 255, 7),
];

fn run_int(p: &ops::PackedQW, x: &[f32], rows: usize, sxw: &[f32], b: &[f32], out: &mut [f32]) {
    let mut xq = Vec::new();
    let round = RoundMode::TiesEven;
    let act = Some(ops::Act::Relu);
    ops::linear_int_packed(x, rows, p, Some(b), 0.04, 117, round, sxw, act, &mut xq, out);
}

#[test]
fn int_kernels_are_bit_identical_across_tiers_and_shapes() {
    let tier = KernelTier::detect();
    let mut rng = Rng::new(0x71E7_0001);
    for bits in [8u8, 4] {
        for &(rows, din, dout) in &SHAPES {
            let w = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.5));
            let qw =
                QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, bits);
            let ps = ops::PackedQW::pack_for(&qw, 1, KernelTier::Scalar);
            let pv = ops::PackedQW::pack_for(&qw, 1, tier);
            let x: Vec<f32> = rng.normal_vec(rows * din, 1.0);
            let sxw: Vec<f32> = qw.scales.iter().map(|&s| 0.04 * s).collect();
            let bias: Vec<f32> = rng.normal_vec(dout, 0.1);
            let mut out_s = vec![0.0f32; rows * dout];
            let mut out_v = vec![0.0f32; rows * dout];
            run_int(&ps, &x, rows, &sxw, &bias, &mut out_s);
            run_int(&pv, &x, rows, &sxw, &bias, &mut out_v);
            assert_eq!(
                out_s, out_v,
                "int{bits} {rows}x{din}x{dout}: {} tier diverged from scalar",
                tier.label()
            );
        }
    }
}

#[test]
fn int_accumulators_match_an_i64_brute_force_exactly() {
    // sxw = 1, zx = 128, activations exactly on the u8 grid: the kernel's
    // f32 output IS the zero-point-corrected accumulator (|acc| < 2^24, so
    // the cast is lossless) — compare it against an i64 reference.
    let tier = KernelTier::detect();
    let mut rng = Rng::new(0xACC_0002);
    for bits in [8u8, 4] {
        for &(rows, din, dout) in
            &[(2usize, 19usize, 3usize), (3, 37, 5), (1, 256, 4), (2, 51, 7)]
        {
            let w = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.5));
            let qw =
                QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, bits);
            let wq = qw.unpacked_data();
            let xu: Vec<u8> = (0..rows * din).map(|_| rng.below(256) as u8).collect();
            let x: Vec<f32> = xu.iter().map(|&u| u as f32 - 128.0).collect();
            let sxw = vec![1.0f32; dout];
            let mut xq = Vec::new();
            let mut out = vec![0.0f32; rows * dout];
            for t in [KernelTier::Scalar, tier] {
                let p = ops::PackedQW::pack_for(&qw, 1, t);
                let round = RoundMode::TiesEven;
                ops::linear_int_packed(
                    &x, rows, &p, None, 1.0, 128, round, &sxw, None, &mut xq, &mut out,
                );
                for r in 0..rows {
                    for c in 0..dout {
                        let acc: i64 = (0..din)
                            .map(|k| xu[r * din + k] as i64 * wq[c * din + k] as i64)
                            .sum();
                        let want = (acc - 128 * qw.row_sums[c] as i64) as f32;
                        assert_eq!(
                            out[r * dout + c],
                            want,
                            "int{bits} {rows}x{din}x{dout} r{r} c{c} on {} tier",
                            t.label()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn f32_panel_kernels_are_bit_identical_across_tiers() {
    let tier = KernelTier::detect();
    let mut rng = Rng::new(0xF32_0003);
    for &(rows, din, dout) in &[(1usize, 5usize, 2usize), (3, 33, 7), (2, 64, 16), (4, 67, 11)] {
        let w = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.3));
        let x: Vec<f32> = rng.normal_vec(rows * din, 1.0);
        let bias: Vec<f32> = rng.normal_vec(dout, 0.1);
        let ps = ops::PackedF32::pack_for(&w, 1, KernelTier::Scalar);
        let pv = ops::PackedF32::pack_for(&w, 1, tier);
        let mut out_s = vec![0.0f32; rows * dout];
        let mut out_v = vec![0.0f32; rows * dout];
        ops::linear_f32_packed(&x, rows, &ps, Some(&bias), Some(ops::Act::Relu), &mut out_s);
        ops::linear_f32_packed(&x, rows, &pv, Some(&bias), Some(ops::Act::Relu), &mut out_v);
        assert_eq!(
            out_s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out_v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "f32 {rows}x{din}x{dout}: {} tier diverged from scalar",
            tier.label()
        );
    }
}

/// Full deployment of the synthetic ResNet at a weight bit-width, with an
/// explicitly requested kernel tier (`None` = auto-detect).
fn deployment(bits: u8, kernel_tier: Option<KernelTier>) -> (CompiledModel, Tensor) {
    let sm = synth::resnet_like(16, 16);
    let (graph, params, _f, _fused) =
        passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    let mut rng = Rng::new(0xDE9_0004);
    let n = 2 * 3 * 16 * 16;
    let x = Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(n, 1.0));
    let fp = fp32_model(graph.clone(), params.clone(), BTreeMap::new());
    let batches: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(n, 1.0))).collect();
    let ranges = calibrate(&fp, &batches, CalibMethod::MinMax).unwrap().ranges;
    let mut qweights = HashMap::new();
    for node in graph.weight_nodes() {
        let key = format!("{}.w", node.name);
        if let Some(w) = params.get(&key) {
            qweights.insert(
                key,
                QWeight::quantize_bits(w, QuantScheme::PerChannelSym, RoundMode::TiesEven, bits),
            );
        }
    }
    let weight_mode = if bits == 4 { WeightMode::Int4 } else { WeightMode::Int8 };
    let model = CompiledModel::new(
        graph,
        params,
        BTreeMap::new(),
        qweights,
        ranges,
        ExecConfig {
            weight_mode,
            act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
            kernel_tier,
        },
    );
    (model, x)
}

#[test]
fn forced_scalar_deployment_matches_detected_tier_bitwise() {
    for bits in [8u8, 4] {
        let (auto, x) = deployment(bits, None);
        let (scalar, _) = deployment(bits, Some(KernelTier::Scalar));
        assert_eq!(scalar.plan().unwrap().kernel_tier(), KernelTier::Scalar);
        assert_eq!(
            auto.plan().unwrap().kernel_tier(),
            KernelTier::detect(),
            "auto plan must resolve the detected tier"
        );
        assert_eq!(
            auto.run(&x).unwrap()[0].data,
            scalar.run(&x).unwrap()[0].data,
            "int{bits}: detected-tier logits diverged from the scalar tier"
        );
        // both tiers stay bit-exact vs the scalar legacy interpreter
        assert_eq!(
            auto.run(&x).unwrap()[0].data,
            auto.run_interpreted(&x).unwrap()[0].data,
            "int{bits}: planned run diverged from the interpreter"
        );
    }
}
