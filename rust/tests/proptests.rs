//! Property-based tests over the coordinator/engine invariants, using the
//! in-repo mini property harness (testutil::prop_check — the vendored crate
//! set has no proptest).

use quant_trim::calib::{calibrate, CalibMethod};
use quant_trim::coordinator::Curriculum;
use quant_trim::engine::{fp32_model, lowp, ops};
use quant_trim::metrics;
use quant_trim::qir::Graph;
use quant_trim::tensor::{
    act_scale_zp, empirical_quantile, pack_int4, packed_row_bytes, subsample, unpack_int4,
    QActTensor, QWeight, QuantScheme, RoundMode, Tensor,
};
use quant_trim::testutil::{prop_check, Rng};

#[test]
fn prop_quantize_dequantize_error_bounded() {
    // |x - dq(q(x))| <= s/2 for in-range x, any scheme/rounding
    prop_check(
        "qdq-bounded",
        200,
        |r| {
            let n = 1 + r.below(64);
            let scale = r.range(0.01, 2.0);
            (r.normal_vec(n, scale), scale)
        },
        |(data, _)| {
            let t = Tensor::new(vec![1, data.len()], data.clone());
            let q = QWeight::quantize(&t, QuantScheme::PerTensorSym, RoundMode::TiesEven);
            let d = q.dequantize();
            let s = q.scales[0];
            data.iter().zip(d.data.iter()).all(|(a, b)| (a - b).abs() <= s / 2.0 + 1e-6)
        },
    );
}

#[test]
fn prop_int4_pack_unpack_roundtrip() {
    // any nibble matrix — odd and even row lengths, including the
    // single-column degenerate — survives packing losslessly, at half (or
    // ceil-half) the bytes
    prop_check(
        "int4-pack-roundtrip",
        300,
        |r| {
            let rows = 1 + r.below(8);
            let per = 1 + r.below(33);
            let vals: Vec<i8> = (0..rows * per).map(|_| r.below(16) as i8 - 8).collect();
            (rows, per, vals)
        },
        |(rows, per, vals)| {
            let packed = pack_int4(vals, *per);
            packed.len() == rows * packed_row_bytes(*per)
                && unpack_int4(&packed, *rows, *per) == *vals
        },
    );
}

#[test]
fn prop_int4_quantize_dequantize_error_bounded() {
    // |x - dq(q4(x))| <= s/2 on the 16-level grid, any scheme
    prop_check(
        "int4-qdq-bounded",
        200,
        |r| {
            let n = 1 + r.below(64);
            let scale = r.range(0.01, 2.0);
            (r.normal_vec(n, scale), scale)
        },
        |(data, _)| {
            let t = Tensor::new(vec![1, data.len()], data.clone());
            let q = QWeight::quantize_bits(&t, QuantScheme::PerTensorSym, RoundMode::TiesEven, 4);
            let d = q.dequantize();
            let s = q.scales[0];
            data.iter().zip(d.data.iter()).all(|(a, b)| (a - b).abs() <= s / 2.0 + 1e-6)
        },
    );
}

#[test]
fn prop_int4_conv_bit_matches_unpacked_twin() {
    // the packed int4 conv path must equal, bitwise, the i8 path run on the
    // same nibble values — storage format must never change arithmetic
    prop_check(
        "int4-conv-exact",
        25,
        |r| {
            let c = 1 + r.below(4);
            let hw = 4 + r.below(5);
            let co = 1 + r.below(6);
            let x = Tensor::new(vec![1, c, hw, hw], r.normal_vec(c * hw * hw, 1.0));
            let w = Tensor::new(vec![co, c, 3, 3], r.normal_vec(co * c * 9, 0.2));
            (x, w)
        },
        |(x, w)| {
            let q4 = QWeight::quantize_bits(w, QuantScheme::PerChannelSym, RoundMode::TiesEven, 4);
            let twin = QWeight::from_parts(q4.shape.clone(), q4.unpacked_data(), q4.scales.clone());
            let (sx, zx) = act_scale_zp(-3.0, 3.0);
            let y4 = ops::conv2d_i8(x, &q4, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
            let y8 = ops::conv2d_i8(x, &twin, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
            y4.data == y8.data
        },
    );
}

#[test]
fn prop_degenerate_act_range_stays_representable() {
    // lo == hi (constant activation) must yield a positive scale, an
    // in-grid zero point, and a constant that round-trips through the grid
    prop_check(
        "degenerate-range",
        300,
        |r| r.range(-6.0, 6.0),
        |&v| {
            let (s, z) = act_scale_zp(v, v);
            if !(s > 0.0 && s.is_finite() && (0..=255).contains(&z)) {
                return false;
            }
            let t = Tensor::new(vec![1], vec![v]);
            let d = QActTensor::quantize(&t, v, v, RoundMode::TiesEven).dequantize();
            // one grid step of slack: the widened range spans [min(v,0), max(v,0)]
            (d.data[0] - v).abs() <= s + 1e-6
        },
    );
}

#[test]
fn prop_act_quant_roundtrip_idempotent() {
    // quantizing an already quant-dequantized tensor with the same params is
    // lossless — the invariant the engine's aq->conv double-quant relies on
    prop_check(
        "aq-idempotent",
        200,
        |r| {
            let n = 1 + r.below(128);
            let lo = -r.range(0.1, 3.0);
            let hi = r.range(0.1, 3.0);
            (r.normal_vec(n, 1.0), lo, hi)
        },
        |(data, lo, hi)| {
            let t = Tensor::new(vec![data.len()], data.clone());
            let q1 = QActTensor::quantize(&t, *lo, *hi, RoundMode::TiesEven);
            let d1 = q1.dequantize();
            let q2 = QActTensor::quantize(&d1, *lo, *hi, RoundMode::TiesEven);
            q1.data == q2.data
        },
    );
}

#[test]
fn prop_zero_always_representable() {
    // asymmetric activation quantization must map 0.0 exactly (paper §2)
    prop_check(
        "zero-exact",
        300,
        |r| {
            let lo = -r.range(0.0, 5.0);
            let hi = r.range(0.01, 5.0);
            (lo, hi)
        },
        |(lo, hi)| {
            let t = Tensor::new(vec![1], vec![0.0]);
            let q = QActTensor::quantize(&t, *lo, *hi, RoundMode::TiesEven);
            q.dequantize().data[0] == 0.0
        },
    );
}

#[test]
fn prop_scale_positive_and_monotone_in_range() {
    prop_check(
        "scale-monotone",
        300,
        |r| (r.range(-4.0, 0.0), r.range(0.01, 4.0), r.range(1.01, 3.0)),
        |(lo, hi, grow)| {
            let (s1, z1) = act_scale_zp(*lo, *hi);
            let (s2, _z2) = act_scale_zp(lo * grow, hi * grow);
            s1 > 0.0 && s2 > s1 && (0..=255).contains(&z1)
        },
    );
}

#[test]
fn prop_empirical_quantile_bounds_and_monotone() {
    prop_check(
        "quantile-monotone",
        200,
        |r| {
            let n = 1 + r.below(500);
            r.normal_vec(n, 1.0)
        },
        |data| {
            let q10 = empirical_quantile(data, 0.1);
            let q50 = empirical_quantile(data, 0.5);
            let q99 = empirical_quantile(data, 0.99);
            let mn = data.iter().cloned().fold(f32::MAX, f32::min);
            let mx = data.iter().cloned().fold(f32::MIN, f32::max);
            q10 <= q50 && q50 <= q99 && q10 >= mn && q99 <= mx
        },
    );
}

#[test]
fn prop_subsample_preserves_membership() {
    prop_check(
        "subsample-members",
        100,
        |r| {
            let n = 1 + r.below(10_000);
            r.normal_vec(n, 1.0)
        },
        |data| {
            let s = subsample(data, 256);
            s.len() <= 256 && s.iter().all(|v| data.contains(v))
        },
    );
}

#[test]
fn prop_reverse_prune_shrinks_scale_never_grows() {
    // paper §3.2: post-pruning step size Delta' < Delta
    prop_check(
        "rp-shrinks-delta",
        200,
        |r| {
            let n = 8 + r.below(256);
            let std = r.range(0.05, 1.0);
            r.normal_vec(n, std)
        },
        |w| {
            let abs: Vec<f32> = w.iter().map(|v| v.abs()).collect();
            let tau = empirical_quantile(&abs, 0.95);
            let clipped: Vec<f32> = w.iter().map(|v| v.clamp(-tau, tau)).collect();
            let d_before = abs.iter().cloned().fold(0.0f32, f32::max) / 127.0;
            let d_after =
                clipped.iter().map(|v| v.abs()).fold(0.0f32, f32::max) / 127.0;
            d_after <= d_before + 1e-9
        },
    );
}

#[test]
fn prop_lambda_schedule_invariants() {
    // monotone, bounded, continuous-ish at phase boundaries for random
    // curriculum hyperparameters
    prop_check(
        "lambda-invariants",
        100,
        |r| {
            let e_w = 1 + r.below(20);
            let e_f = e_w + 1 + r.below(40);
            let h = 1 + r.below(30);
            (e_w, e_f, h)
        },
        |(e_w, e_f, h)| {
            let c = Curriculum { e_w: *e_w, e_f: *e_f, horizon: *h, ..Curriculum::cifar() };
            let mut prev = -1.0f64;
            for t in 0..(e_f + h + 10) {
                let v = c.lam(t);
                if v < prev - 1e-12 || !(0.0..=1.0).contains(&v) {
                    return false;
                }
                prev = v;
            }
            // boundary values
            c.lam(*e_w) == 0.0 && (c.lam(*e_f) - 0.5).abs() < 1e-9 && c.lam(e_f + h) == 1.0
        },
    );
}

#[test]
fn prop_bf16_f16_roundtrips_are_projections() {
    prop_check(
        "lowp-projection",
        300,
        |r| r.normal() * 10f32.powi(r.below(6) as i32 - 3),
        |x| {
            let b = lowp::bf16(*x);
            let f = lowp::f16(*x);
            // idempotent
            lowp::bf16(b) == b && lowp::f16(f) == f
        },
    );
}

#[test]
fn prop_int8_conv_tracks_f32_within_quant_noise() {
    prop_check(
        "conv-i8-close",
        25,
        |r| {
            let c = 1 + r.below(4);
            let hw = 4 + r.below(5);
            let co = 1 + r.below(6);
            let x = Tensor::new(vec![1, c, hw, hw], r.normal_vec(c * hw * hw, 1.0));
            let w = Tensor::new(vec![co, c, 3, 3], r.normal_vec(co * c * 9, 0.2));
            (x, w)
        },
        |(x, w)| {
            let yf = ops::conv2d_f32(x, w, None, 1, 1, 1);
            let qw = QWeight::quantize(w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
            let lo = x.data.iter().cloned().fold(f32::MAX, f32::min);
            let hi = x.data.iter().cloned().fold(f32::MIN, f32::max);
            let (sx, zx) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
            let yq = ops::conv2d_i8(x, &qw, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
            metrics::snr_db(&yf.data, &yq.data) > 18.0
        },
    );
}

#[test]
fn prop_calibration_ranges_cover_bulk() {
    // calibrated (lo,hi) must cover at least the central 98% of observed data
    let graph = Graph::parse(
        "qir p v1\noutputs r\n\
         node input image inputs=- shape=4,6,6\n\
         node relu r inputs=image shape=4,6,6\n",
    )
    .unwrap();
    prop_check(
        "calib-covers-bulk",
        10,
        |r| {
            let batches: Vec<Tensor> = (0..3)
                .map(|_| Tensor::new(vec![2, 4, 6, 6], (0..288).map(|_| r.heavy_tail(0.01, 8.0)).collect()))
                .collect();
            batches
        },
        |batches| {
            let model = fp32_model(graph.clone(), Default::default(), Default::default());
            for m in [CalibMethod::MinMax, CalibMethod::Percentile(0.999), CalibMethod::Mse] {
                let c = calibrate(&model, batches, m).unwrap();
                let (lo, hi) = c.ranges["image"];
                let mut all: Vec<f32> = Vec::new();
                for b in batches {
                    all.extend_from_slice(&b.data);
                }
                let q01 = empirical_quantile(&all, 0.01);
                let q99 = empirical_quantile(&all, 0.99);
                if lo > q01 || hi < q99 {
                    return false;
                }
            }
            true
        },
    );
}
