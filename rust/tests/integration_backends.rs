//! Integration over the deployment substrate: vendor-backend compilation on
//! real exported models, precision paths, PTQ baselines, QAT-scale
//! consumption, and the engine-vs-Pallas device-forward cross-check.

use std::path::PathBuf;

use quant_trim::backends::{all_backends, backend_by_name, CheckpointView, PtqOptions, RangeSource};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::{CallExtras, TrainState};
use quant_trim::data::{gen_cls_batch, ClsSpec};
use quant_trim::engine::fp32_model;
use quant_trim::metrics::snr_db;
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::qir::Graph;
use quant_trim::runtime::{Manifest, Runtime};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::{synth, Rng};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("resnet18_c10.manifest").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

fn load_state(dir: &PathBuf, model: &str) -> (Graph, TrainState) {
    let graph = Graph::load(dir.join(format!("{model}.qir"))).unwrap();
    let ck = Checkpoint::load(dir.join(format!("{model}.init.qtckpt"))).unwrap();
    (graph, TrainState::from_checkpoint(&ck))
}

#[test]
fn every_backend_compiles_and_runs_resnet() {
    let Some(dir) = artifacts_dir() else { return };
    let (graph, state) = load_state(&dir, "resnet18_c10");
    let task = ClsSpec::cifar10();
    let calib: Vec<Tensor> = (0..2).map(|i| gen_cls_batch(task, 8, 100 + i).images).collect();
    let b = gen_cls_batch(task, 4, 7);
    let reference = fp32_model(graph.clone(), state.params.clone(), state.bn.clone());
    let ref_logits = reference.run(&b.images).unwrap().remove(0);
    for be in all_backends() {
        for prec in be.precisions.clone() {
            let view = CheckpointView {
                graph: &graph,
                params: &state.params,
                bn: &state.bn,
                qstate: &state.qstate,
            };
            let dep = be
                .compile(view, prec, RangeSource::Calibration, &calib, PtqOptions::default())
                .unwrap_or_else(|e| panic!("{} {:?}: {e}", be.name, prec));
            let out = dep.model.run(&b.images).unwrap().remove(0);
            assert_eq!(out.shape, ref_logits.shape, "{} {:?}", be.name, prec);
            let snr = snr_db(&ref_logits.data, &out.data);
            // CNN INT8 on an init checkpoint should stay well above 8 dB;
            // float paths essentially exact
            // entropy calibration (hardware_c / TensorRT-style) is the most
            // clipping-aggressive observer — part of the cross-backend
            // variance the paper targets — so the INT8 floor is permissive
            let floor = match prec {
                Precision::Fp32 => 100.0,
                Precision::Fp16 => 40.0,
                Precision::Bf16 => 20.0,
                Precision::Int8 => 5.0,
                // 16-level weight grid: coarser by construction, but still
                // far from noise on a CNN init checkpoint
                Precision::Int4 => 2.0,
            };
            assert!(snr > floor, "{} {:?}: snr {snr:.1} dB below {floor}", be.name, prec);
        }
    }
}

#[test]
fn strict_backend_requires_calibration_for_int8() {
    let Some(dir) = artifacts_dir() else { return };
    let (graph, state) = load_state(&dir, "resnet18_c10");
    let ha = backend_by_name("hardware_a").unwrap();
    // MAP checkpoint (no qstate) without calibration data must fail
    let empty_q = Default::default();
    let view = CheckpointView {
        graph: &graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &empty_q,
    };
    let err =
        ha.compile(view, Precision::Int8, RangeSource::Calibration, &[], PtqOptions::default());
    assert!(err.is_err(), "hardware_a must demand a calibration dataset");
    // hardware_d ("compiler-provided static scaling") tolerates it
    let hd = backend_by_name("hardware_d").unwrap();
    let view = CheckpointView {
        graph: &graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    hd.compile(view, Precision::Int8, RangeSource::QatScales, &[], PtqOptions::default())
        .expect("hardware_d compiles from embedded QAT scales without calib data");
}

#[test]
fn qat_scales_match_calibration_quality_on_init() {
    let Some(dir) = artifacts_dir() else { return };
    let (graph, state) = load_state(&dir, "resnet18_c10");
    let task = ClsSpec::cifar10();
    let calib: Vec<Tensor> = (0..2).map(|i| gen_cls_batch(task, 8, 100 + i).images).collect();
    let b = gen_cls_batch(task, 8, 9);
    let reference = fp32_model(graph.clone(), state.params.clone(), state.bn.clone());
    let ref_logits = reference.run(&b.images).unwrap().remove(0);
    let hd = backend_by_name("hardware_d").unwrap();
    let mut snrs = Vec::new();
    for src in [RangeSource::QatScales, RangeSource::Calibration] {
        let view = CheckpointView {
            graph: &graph,
            params: &state.params,
            bn: &state.bn,
            qstate: &state.qstate,
        };
        let dep = hd.compile(view, Precision::Int8, src, &calib, PtqOptions::default()).unwrap();
        let out = dep.model.run(&b.images).unwrap().remove(0);
        snrs.push(snr_db(&ref_logits.data, &out.data));
    }
    // On an INIT checkpoint the embedded QAT activation ranges are still the
    // generic [0, 6] seeds — untrained, so only *finite* fidelity is required
    // (trained-checkpoint QAT quality is asserted by the examples and the
    // engine-vs-device-forward test). Calibration must be healthy regardless.
    assert!(snrs[0].is_finite(), "qat-scale deployment must run: {snrs:?}");
    assert!(snrs[1] > 10.0, "calibration source must be healthy: {snrs:?}");
}

#[test]
fn ptq_baseline_options_run() {
    let Some(dir) = artifacts_dir() else { return };
    let (graph, state) = load_state(&dir, "resnet18_c10");
    let task = ClsSpec::cifar10();
    let calib: Vec<Tensor> = (0..2).map(|i| gen_cls_batch(task, 8, 300 + i).images).collect();
    let ha = backend_by_name("hardware_a").unwrap();
    let b = gen_cls_batch(task, 4, 11);
    let reference = fp32_model(graph.clone(), state.params.clone(), state.bn.clone());
    let ref_logits = reference.run(&b.images).unwrap().remove(0);
    for ptq in [
        PtqOptions::default(),
        PtqOptions { equalization: true, adaround: false },
        PtqOptions { equalization: true, adaround: true },
    ] {
        let view = CheckpointView {
            graph: &graph,
            params: &state.params,
            bn: &state.bn,
            qstate: &state.qstate,
        };
        let dep =
            ha.compile(view, Precision::Int8, RangeSource::Calibration, &calib, ptq).unwrap();
        let out = dep.model.run(&b.images).unwrap().remove(0);
        let snr = snr_db(&ref_logits.data, &out.data);
        assert!(snr > 5.0, "PTQ {ptq:?} snr too low: {snr}");
    }
}

#[test]
fn vit_attention_falls_back_on_restrictive_npus() {
    let Some(dir) = artifacts_dir() else { return };
    let graph = Graph::load(dir.join("vit.qir")).unwrap();
    let ha = backend_by_name("hardware_a").unwrap();
    let perf = ha.perf(&graph, Precision::Int8, 1);
    assert!(perf.fallback_ops > 0, "attention/layernorm must fall back on hardware_a");
    let hd = backend_by_name("hardware_d").unwrap();
    let perf_d = hd.perf(&graph, Precision::Int8, 1);
    assert_eq!(perf_d.fallback_ops, 0, "hardware_d covers the transformer ops");
    // fallbacks cost real latency
    assert!(perf.latency_ms > perf_d.latency_ms);
}

#[test]
fn engine_int8_agrees_with_pallas_device_forward() {
    // The exported device_forward (Pallas fake-quant at lam=1 with qstate
    // scales) and the Rust engine under the same contract simulate the same
    // static-INT8 deployment of the same checkpoint. Assert strong agreement
    // (SNR + argmax), not bit-equality: the engine additionally quantizes
    // conv inputs, as a real integer pipeline does.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(dir.join("resnet18_c10.manifest")).unwrap();
    let (graph, state) = load_state(&dir, "resnet18_c10");
    let spec = man.fns["device_forward"].clone();
    let bsz = spec.args.iter().find(|s| s.role == "data").unwrap().shape[0];
    let b = gen_cls_batch(ClsSpec::cifar10(), bsz, 23);

    let f = rt.load_fn(&man, "device_forward").unwrap();
    let extras = CallExtras { data: Some(&b.images), ..Default::default() };
    let args = state.marshal(&spec, &extras).unwrap();
    let outs = f.call(&args).unwrap();
    let jax_dev = quant_trim::runtime::literal_to_tensor(&outs[0], &spec.rets[0].shape).unwrap();

    let hd = backend_by_name("hardware_d").unwrap();
    let calib: Vec<Tensor> =
        (0..2).map(|i| gen_cls_batch(ClsSpec::cifar10(), 8, 700 + i).images).collect();
    let view = CheckpointView {
        graph: &graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    let dep = hd
        .compile(view, Precision::Int8, RangeSource::QatScales, &calib, PtqOptions::default())
        .unwrap();
    let rust_dev = dep.model.run(&b.images).unwrap().remove(0);
    let snr = snr_db(&jax_dev.data, &rust_dev.data);
    assert!(snr > 8.0, "rust int8 engine vs pallas device forward: snr {snr:.1} dB");
    // argmax agreement on most samples
    let c = jax_dev.shape[1];
    let mut agree = 0;
    for i in 0..bsz {
        let am = |t: &Tensor| {
            t.data[i * c..(i + 1) * c]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        if am(&jax_dev) == am(&rust_dev) {
            agree += 1;
        }
    }
    assert!(agree * 10 >= bsz * 7, "argmax agreement too low: {agree}/{bsz}");
}

#[test]
fn dynamic_scaling_deployment_is_calibration_free() {
    // jetson_agx_orin normally DEMANDS a calibration dataset for INT8 —
    // a dynamic-scaling request removes that dependence entirely: it
    // compiles with ZERO calibration batches and serves from live ranges
    let sm = synth::resnet_like(16, 16);
    let qstate = Default::default();
    let be = backend_by_name("jetson_agx_orin").unwrap();
    assert!(be.needs_calib_for_int && be.supports_dynamic_act);
    let view = CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let err = be.compile(view, Precision::Int8, RangeSource::Calibration, &[], PtqOptions::default());
    assert!(err.is_err(), "static INT8 without calibration must be refused");
    let view = CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let dep = be
        .compile_scaled(
            view,
            Precision::Int8,
            ActScaling::Dynamic,
            RangeSource::Calibration,
            &[],
            PtqOptions::default(),
        )
        .expect("dynamic INT8 compiles with no calibration data at all");
    assert_eq!(dep.act_scaling, ActScaling::Dynamic);
    assert!(!dep.scaling_fell_back());
    assert!(dep.model.act_ranges.is_empty(), "dynamic deployment ships no static ranges");
    let x = Tensor::new(vec![1, 3, 16, 16], Rng::new(0xDCA).normal_vec(3 * 256, 1.0));
    let planned = dep.model.run(&x).unwrap();
    let interp = dep.model.run_interpreted(&x).unwrap();
    assert_eq!(planned[0].data, interp[0].data, "deployed dynamic int8 plan must be bit-exact");
    assert!(planned[0].data.iter().all(|v| v.is_finite()));
}

#[test]
fn dynamic_request_falls_back_to_static_on_strict_backends() {
    // hardware_a bakes every range at compile time: a dynamic request
    // compiles, but as the static engine — and says so on the deployment
    // (mirroring the INT4→INT8 weight fallback)
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xDCB);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let qstate = Default::default();
    let be = backend_by_name("hardware_a").unwrap();
    assert!(!be.supports_dynamic_act);
    let compile_at = |scaling: ActScaling| {
        let view =
            CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
        be.compile_scaled(
            view,
            Precision::Int8,
            scaling,
            RangeSource::Calibration,
            &calib,
            PtqOptions::default(),
        )
        .unwrap()
    };
    let dep = compile_at(ActScaling::Dynamic);
    assert_eq!(dep.requested_scaling, ActScaling::Dynamic);
    assert_eq!(dep.act_scaling, ActScaling::Static);
    assert!(dep.scaling_fell_back());
    assert!(!dep.model.act_ranges.is_empty(), "fallback ships calibrated static ranges");
    // the fallback deployment IS the static deployment, bit for bit
    let dep_static = compile_at(ActScaling::Static);
    let x = Tensor::new(vec![1, 3, 16, 16], rng.normal_vec(3 * 256, 1.0));
    assert_eq!(dep.model.run(&x).unwrap()[0].data, dep_static.model.run(&x).unwrap()[0].data);
}

#[test]
fn dynamic_scaling_costs_modelled_latency() {
    // the perf model charges the per-node range-scan term: a dynamic
    // deployment of the same graph must model slower than its static twin
    let sm = synth::resnet_like(16, 16);
    let be = backend_by_name("hardware_d").unwrap();
    let st = be.perf_scaled(&sm.graph, Precision::Int8, ActScaling::Static, 1);
    let dy = be.perf_scaled(&sm.graph, Precision::Int8, ActScaling::Dynamic, 1);
    assert!(dy.latency_ms > st.latency_ms, "{} vs {}", dy.latency_ms, st.latency_ms);
}

#[test]
fn bf16_hybrid_beats_int8_fidelity_on_hardware_b() {
    let Some(dir) = artifacts_dir() else { return };
    let (graph, state) = load_state(&dir, "resnet18_c10");
    let task = ClsSpec::cifar10();
    let calib: Vec<Tensor> = (0..2).map(|i| gen_cls_batch(task, 8, 400 + i).images).collect();
    let b = gen_cls_batch(task, 4, 17);
    let reference = fp32_model(graph.clone(), state.params.clone(), state.bn.clone());
    let ref_logits = reference.run(&b.images).unwrap().remove(0);
    let hb = backend_by_name("hardware_b").unwrap();
    let mut snr = std::collections::HashMap::new();
    for prec in [Precision::Bf16, Precision::Int8] {
        let view = CheckpointView {
            graph: &graph,
            params: &state.params,
            bn: &state.bn,
            qstate: &state.qstate,
        };
        let dep =
            hb.compile(view, prec, RangeSource::Calibration, &calib, PtqOptions::default()).unwrap();
        let out = dep.model.run(&b.images).unwrap().remove(0);
        snr.insert(prec.label(), snr_db(&ref_logits.data, &out.data));
    }
    assert!(
        snr["BF16"] > snr["INT8"],
        "W8/ABF16 hybrid should be higher-fidelity than full INT8: {snr:?}"
    );
}
