//! Soundness suite for the static plan auditor (`engine::verify` +
//! `qir::analysis`).
//!
//! The auditor's contract is that its propagated intervals are *sound*:
//! every value the runtime can produce lies inside the predicted per-node
//! bound, and every i32 accumulator of an integer GEMM lies inside the
//! predicted accumulator bound. This suite checks that contract
//! empirically across the full `ExecConfig` matrix — F32/Bf16/F16/Int8/
//! DynInt8 activations × F32/Int8/Int4 weights — on the fixed synthetic
//! graphs AND on seeded random CNN topologies, then checks the negative
//! direction: every `Sabotage` corruption class must raise its expected
//! finding code at ERROR severity.

use std::collections::{BTreeMap, HashMap};

use quant_trim::calib::{calibrate, CalibMethod};
use quant_trim::engine::ops::quantize_slice;
use quant_trim::engine::verify::{has_errors, Sabotage, Severity};
use quant_trim::engine::{fp32_model, ActMode, CompiledModel, ExecConfig, WeightMode};
use quant_trim::qir::passes;
use quant_trim::tensor::{act_scale_zp, QWeight, QuantScheme, RoundMode, Tensor};
use quant_trim::testutil::synth::{self, SynthModel};
use quant_trim::testutil::Rng;

/// Quantize every weight-bearing node of a graph at a weight bit-width
/// (same shipping set a backend would build).
fn quantize_weights(
    graph: &quant_trim::qir::Graph,
    params: &BTreeMap<String, Tensor>,
    bits: u8,
) -> HashMap<String, QWeight> {
    let (scheme, round) = (QuantScheme::PerChannelSym, RoundMode::TiesEven);
    let mut q = HashMap::new();
    for n in graph.weight_nodes() {
        let keys: Vec<String> = match n.kind.as_str() {
            "attention" => {
                ["wq", "wk", "wv", "wo"].iter().map(|m| format!("{}.{m}", n.name)).collect()
            }
            _ => vec![format!("{}.w", n.name)],
        };
        for key in keys {
            if let Some(w) = params.get(&key) {
                q.insert(key, QWeight::quantize_bits(w, scheme, round, bits));
            }
        }
    }
    q
}

/// Calibrated MinMax ranges for every node.
fn ranges_for(
    graph: &quant_trim::qir::Graph,
    params: &BTreeMap<String, Tensor>,
    batches: &[Tensor],
) -> HashMap<String, (f32, f32)> {
    let fp = fp32_model(graph.clone(), params.clone(), BTreeMap::new());
    calibrate(&fp, batches, CalibMethod::MinMax).unwrap().ranges
}

fn minmax(data: &[f32]) -> (f32, f32) {
    let mut lo = f32::MAX;
    let mut hi = f32::MIN;
    for &v in data {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    (lo, hi)
}

/// Audit a lowered graph at every ExecConfig and assert the propagated
/// interval of every node contains every value the interpreter observes.
fn check_soundness(sm: &SynthModel, label: &str, seed: u64) {
    let (graph, params, _factors, _fused) =
        passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    let in_shape =
        graph.nodes.iter().find(|n| n.kind == "input").expect("graph has input").shape.clone();
    let full: Vec<usize> = std::iter::once(2).chain(in_shape.iter().copied()).collect();
    let n: usize = full.iter().product();
    let mut rng = Rng::new(seed);
    let batches: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(full.clone(), rng.normal_vec(n, 1.0))).collect();
    let ranges = ranges_for(&graph, &params, &batches);
    let q8 = quantize_weights(&graph, &params, 8);
    let q4 = quantize_weights(&graph, &params, 4);
    let x = Tensor::new(full, rng.normal_vec(n, 1.0));
    let (lo, hi) = minmax(&x.data);

    let act_modes = [
        ActMode::F32,
        ActMode::Bf16,
        ActMode::F16,
        ActMode::Int8 { round: RoundMode::TiesEven },
        ActMode::DynInt8 { round: RoundMode::TiesEven },
    ];
    for weight_mode in [WeightMode::F32, WeightMode::Int8, WeightMode::Int4] {
        let qweights = if weight_mode == WeightMode::Int4 { &q4 } else { &q8 };
        for act_mode in act_modes {
            let cfg = ExecConfig { weight_mode, act_mode, kernel_tier: None };
            // the dynamic path is calibration-free by contract
            let cfg_ranges = if act_mode.is_dynamic() { HashMap::new() } else { ranges.clone() };
            let model = CompiledModel::new(
                graph.clone(),
                params.clone(),
                BTreeMap::new(),
                qweights.clone(),
                cfg_ranges,
                cfg,
            );
            let report = model.audit(Some((lo, hi))).unwrap();
            let errs: Vec<_> =
                report.findings.iter().filter(|f| f.severity == Severity::Error).collect();
            assert!(errs.is_empty(), "{label} {cfg:?}: seed graph must audit clean, got {errs:?}");

            let mut checked = 0usize;
            model
                .run_observe(&x, &mut |name, t| {
                    let r = report
                        .reports
                        .get(name)
                        .unwrap_or_else(|| panic!("{label} {cfg:?}: no report for node {name}"));
                    for &v in &t.data {
                        if v.is_nan() {
                            // NaN can only arise downstream of a predicted
                            // storage-format overflow (±∞ bound)
                            assert!(
                                !r.out.is_finite(),
                                "{label} {cfg:?} {name}: NaN under a finite bound {:?}",
                                r.out
                            );
                            continue;
                        }
                        assert!(
                            r.out.contains(v as f64),
                            "{label} {cfg:?} {name}: observed {v} outside predicted {:?}",
                            r.out
                        );
                        checked += 1;
                    }
                })
                .unwrap();
            assert!(checked > 0, "{label} {cfg:?}: observer saw no values");
        }
    }
}

#[test]
fn interval_analysis_is_sound_on_resnet_style() {
    check_soundness(&synth::resnet_like(16, 16), "resnet-like", 0x50D_0001);
}

#[test]
fn interval_analysis_is_sound_on_vit_style() {
    check_soundness(&synth::vit_like(), "vit-like", 0x50D_0002);
}

#[test]
fn interval_analysis_is_sound_on_random_topologies() {
    for seed in 1u64..=4 {
        let sm = synth::random_cnn(seed);
        check_soundness(&sm, &format!("random-cnn-{seed}"), 0x50D_1000 + seed);
    }
}

#[test]
fn predicted_accumulator_bounds_contain_runtime_accumulators() {
    // Recompute the i32 accumulators of the head linear GEMM exactly as the
    // engine does (same grid, same rounding, same payload) and assert every
    // one — raw and zero-point-corrected — lies inside the audited bounds,
    // at both weight bit-widths.
    for (label, sm) in
        [("resnet-like", synth::resnet_like(16, 16)), ("random-cnn", synth::random_cnn(0xACC))]
    {
        let (graph, params, _f, _fused) =
            passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
        let in_shape = graph.nodes.iter().find(|n| n.kind == "input").unwrap().shape.clone();
        let full: Vec<usize> = std::iter::once(2).chain(in_shape.iter().copied()).collect();
        let n: usize = full.iter().product();
        let mut rng = Rng::new(0xACC_5EED);
        let batches: Vec<Tensor> =
            (0..2).map(|_| Tensor::new(full.clone(), rng.normal_vec(n, 1.0))).collect();
        let ranges = ranges_for(&graph, &params, &batches);
        let x = Tensor::new(full, rng.normal_vec(n, 1.0));
        let (lo, hi) = minmax(&x.data);

        let head = graph.nodes.iter().find(|g| g.kind == "linear").expect("head linear");
        let producer = head.inputs[0].clone();
        for bits in [8u8, 4] {
            let weight_mode = if bits == 4 { WeightMode::Int4 } else { WeightMode::Int8 };
            let model = CompiledModel::new(
                graph.clone(),
                params.clone(),
                BTreeMap::new(),
                quantize_weights(&graph, &params, bits),
                ranges.clone(),
                ExecConfig {
                    weight_mode,
                    act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
                    kernel_tier: None,
                },
            );
            let report = model.audit(Some((lo, hi))).unwrap();
            let la = report
                .layers
                .iter()
                .find(|l| l.node == head.name)
                .expect("head linear must be audited as an integer GEMM");
            assert_eq!(la.bits, bits);

            let mut x_in: Option<Vec<f32>> = None;
            model
                .run_observe(&x, &mut |name, t| {
                    if name == producer {
                        x_in = Some(t.data.clone());
                    }
                })
                .unwrap();
            let x_in = x_in.expect("producer observed");

            // the engine's static input grid: producer range, zero-spanning
            let &(rlo, rhi) = ranges.get(&producer).expect("producer calibrated");
            let (s, z) = act_scale_zp(rlo.min(0.0), rhi.max(rlo + 1e-6));
            let xq = quantize_slice(&x_in, s, z, RoundMode::TiesEven);

            let qw = &model.qweights[&format!("{}.w", head.name)];
            let wq = qw.unpacked_data();
            let dout = qw.shape[0];
            let k = wq.len() / dout;
            assert_eq!(la.k, k, "{label}: audited K must match the GEMM K");
            let rows = xq.len() / k;
            assert!(rows > 0);
            for r in 0..rows {
                let xrow = &xq[r * k..(r + 1) * k];
                for c in 0..dout {
                    let wrow = &wq[c * k..(c + 1) * k];
                    let acc: i64 =
                        wrow.iter().zip(xrow).map(|(&w, &u)| w as i64 * u as i64).sum();
                    let corrected = acc - z as i64 * qw.row_sums[c] as i64;
                    assert!(
                        corrected >= la.acc.lo && corrected <= la.acc.hi,
                        "{label} int{bits}: accumulator {corrected} outside [{}, {}]",
                        la.acc.lo,
                        la.acc.hi
                    );
                    assert!(
                        acc.abs() <= la.acc.max_abs && corrected.abs() <= la.acc.max_abs,
                        "{label} int{bits}: |acc| exceeds audited max_abs {}",
                        la.acc.max_abs
                    );
                }
            }
        }
    }
}

#[test]
fn verifier_catches_every_injected_corruption() {
    // The negative direction: a clean deployment audits clean, and each
    // sabotage class raises exactly its expected finding code at ERROR.
    let sm = synth::resnet_like(16, 16);
    let (graph, params, _f, _fused) =
        passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    let mut rng = Rng::new(0x5AB0);
    let batches: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let model = CompiledModel::new(
        graph.clone(),
        params.clone(),
        BTreeMap::new(),
        quantize_weights(&graph, &params, 8),
        ranges_for(&graph, &params, &batches),
        ExecConfig {
            weight_mode: WeightMode::Int8,
            act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
            kernel_tier: None,
        },
    );
    assert!(!has_errors(&model.verify().unwrap()), "clean deployment must verify clean");
    for s in Sabotage::ALL {
        let findings = model.verify_sabotaged(s).unwrap();
        assert!(
            findings
                .iter()
                .any(|f| f.severity == Severity::Error && f.code == s.expected_code()),
            "sabotage {:?} must raise {} at ERROR severity, got: {findings:?}",
            s.name(),
            s.expected_code()
        );
    }
}
