//! Steady-state contracts of the planned executor:
//!
//! 1. **Zero heap allocations** in a warm `run_with` — a counting global
//!    allocator (thread-local event counter, so pool-worker allocations on
//!    other threads don't pollute the measurement… and they must not
//!    allocate either, but that is the pool's own contract) asserts that
//!    the SECOND run of a planned int8 synthetic ResNet touches the
//!    allocator exactly zero times on the executing thread.
//! 2. **Pool determinism** — the same planned model produces bit-identical
//!    logits on worker pools of 1, 2 and 8 lanes (chunking never changes
//!    per-output accumulation order).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::{BTreeMap, HashMap};

use quant_trim::engine::pool::{self, ThreadPool};
use quant_trim::engine::{ActMode, CompiledModel, ExecConfig, ExecScratch, WeightMode};
use quant_trim::qir::passes;
use quant_trim::tensor::{QWeight, QuantScheme, RoundMode, Tensor};
use quant_trim::testutil::synth;
use quant_trim::testutil::Rng;

thread_local! {
    static ALLOC_EVENTS: Cell<usize> = const { Cell::new(0) };
}

/// Counts alloc/realloc events on the calling thread, then defers to the
/// system allocator. Deallocations are free to happen (a dealloc returns
/// memory; it cannot grow a warm run's footprint) but allocations and
/// reallocations are the regression being gated.
struct CountingAlloc;

fn bump() {
    // try_with: the allocator runs during TLS teardown too, when the
    // counter may already be destroyed — those events are not ours to count
    let _ = ALLOC_EVENTS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_events() -> usize {
    ALLOC_EVENTS.with(|c| c.get())
}

/// Planned int8 deployment of the wide synthetic ResNet (the bench model —
/// its GEMMs cross the parallel-dispatch threshold, so the persistent pool
/// path is exercised, not just the inline one).
fn int8_model() -> (CompiledModel, Tensor) {
    let sm = synth::resnet_like(32, 64);
    let (graph, params, _f, _fused) =
        passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    let mut rng = Rng::new(0x57EAD);
    let x = Tensor::new(vec![2, 3, 32, 32], rng.normal_vec(2 * 3 * 32 * 32, 1.0));
    let fp = quant_trim::engine::fp32_model(graph.clone(), params.clone(), BTreeMap::new());
    let batches: Vec<Tensor> = (0..2)
        .map(|_| Tensor::new(vec![2, 3, 32, 32], rng.normal_vec(2 * 3 * 32 * 32, 1.0)))
        .collect();
    let ranges =
        quant_trim::calib::calibrate(&fp, &batches, quant_trim::calib::CalibMethod::MinMax)
            .unwrap()
            .ranges;
    let mut qweights = HashMap::new();
    for n in graph.weight_nodes() {
        let key = format!("{}.w", n.name);
        if let Some(w) = params.get(&key) {
            qweights.insert(
                key,
                QWeight::quantize(w, QuantScheme::PerChannelSym, RoundMode::TiesEven),
            );
        }
    }
    let model = CompiledModel::new(
        graph,
        params,
        BTreeMap::new(),
        qweights,
        ranges,
        ExecConfig {
            weight_mode: WeightMode::Int8,
            act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
            kernel_tier: None,
        },
    );
    (model, x)
}

#[test]
fn warm_planned_run_makes_zero_heap_allocations() {
    let (model, x) = int8_model();
    model.plan().unwrap(); // compile outside the measured region
    let mut scratch = ExecScratch::new();
    // warmup: sizes the slot arena, im2col/xq/mat scratch, output copies,
    // and spins up the global pool (worker spawn + queue reservation)
    let warm = model.run_with(&x, &mut scratch).unwrap()[0].data.clone();

    let before = alloc_events();
    let outs = model.run_with(&x, &mut scratch).unwrap();
    let after = alloc_events();
    assert_eq!(outs[0].data, warm, "warm rerun changed the logits");
    assert_eq!(
        after - before,
        0,
        "steady-state planned run must not touch the allocator (got {} events)",
        after - before
    );
}

#[test]
fn warm_runs_stay_allocation_free_across_repeats() {
    // ten consecutive warm runs: not a single allocation between them —
    // the arena really is at its high-water mark, not just lucky once
    let (model, x) = int8_model();
    let mut scratch = ExecScratch::new();
    model.run_with(&x, &mut scratch).unwrap();
    let before = alloc_events();
    for _ in 0..10 {
        model.run_with(&x, &mut scratch).unwrap();
    }
    assert_eq!(alloc_events() - before, 0, "a repeat run allocated");
}

#[test]
fn pool_size_does_not_change_planned_results() {
    let (model, x) = int8_model();
    let reference = model.run_interpreted(&x).unwrap();
    for threads in [1usize, 2, 8] {
        let p = ThreadPool::new(threads);
        let mut scratch = ExecScratch::new();
        let outs = pool::with_pool(&p, || {
            model.run_with(&x, &mut scratch).map(|o| o.to_vec())
        })
        .unwrap();
        assert_eq!(
            outs[0].data, reference[0].data,
            "planned int8 logits drifted at pool size {threads}"
        );
    }
}
