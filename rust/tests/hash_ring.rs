//! Property suite for the consistent-hash ring (`coordinator::ring`): the
//! statistical load-balance bound at >=128 vnodes and the minimal-disruption
//! property under join/leave, swept over seeded random membership sequences
//! (testutil::Rng — fully deterministic, no network, no clock).

use std::collections::{BTreeMap, BTreeSet};

use quant_trim::coordinator::ring::{stable_hash, HashRing};
use quant_trim::testutil::Rng;

/// Keys used by the distribution / disruption sweeps.
fn keys(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("request-key-{i}")).collect()
}

/// Owner of every key, as a map key -> node.
fn ownership(ring: &HashRing, keys: &[String]) -> BTreeMap<String, String> {
    keys.iter()
        .map(|k| (k.clone(), ring.primary(k).expect("non-empty ring").to_string()))
        .collect()
}

/// Per-node key counts.
fn shares(owners: &BTreeMap<String, String>) -> BTreeMap<String, usize> {
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    for owner in owners.values() {
        *counts.entry(owner.clone()).or_insert(0) += 1;
    }
    counts
}

#[test]
fn stable_hash_is_deterministic_and_spreads() {
    assert_eq!(stable_hash(b"abc"), stable_hash(b"abc"));
    assert_ne!(stable_hash(b"abc"), stable_hash(b"abd"));
    // avalanche sanity: low bits of sequential keys should not be constant
    let low_bits: BTreeSet<u64> = (0..64).map(|i| stable_hash(format!("k{i}").as_bytes()) & 0xff).collect();
    assert!(low_bits.len() > 32, "low byte shows only {} values over 64 keys", low_bits.len());
}

/// At >=128 vnodes the per-node share of a large key population stays within
/// a band around the ideal 1/N — the bound the router's throughput-scaling
/// assertion leans on. Swept over node counts 2..=8.
#[test]
fn key_distribution_is_balanced_at_128_vnodes() {
    let keys = keys(4096);
    for n in 2..=8usize {
        let mut ring = HashRing::new(128);
        for i in 0..n {
            ring.add_node(&format!("node-{i}"));
        }
        let owners = ownership(&ring, &keys);
        let counts = shares(&owners);
        assert_eq!(counts.len(), n, "every node owns at least one key");
        let ideal = keys.len() as f64 / n as f64;
        for (node, count) in &counts {
            let ratio = *count as f64 / ideal;
            // generous statistical band: 128 vnodes keeps empirical shares
            // well inside [0.5, 1.6]x ideal for these populations
            assert!(
                (0.5..=1.6).contains(&ratio),
                "{node} owns {count} keys at n={n} ({ratio:.2}x ideal {ideal:.0})"
            );
        }
    }
}

/// Fewer vnodes must still cover all nodes (no starvation), even if the
/// balance band is wider — guards the `vnodes.max(1)` clamp too.
#[test]
fn low_vnode_rings_still_cover_all_nodes() {
    let keys = keys(4096);
    for vnodes in [1usize, 8, 32] {
        let mut ring = HashRing::new(vnodes);
        for i in 0..4 {
            ring.add_node(&format!("node-{i}"));
        }
        let counts = shares(&ownership(&ring, &keys));
        assert!(!counts.is_empty(), "someone owns keys at vnodes={vnodes}");
    }
}

/// Node join moves at most ~K/N keys, and every moved key moves *to* the
/// joiner (nobody else's placement changes).
#[test]
fn join_moves_at_most_k_over_n_keys_and_only_to_the_joiner() {
    let keys = keys(4096);
    for n in 2..=6usize {
        let mut ring = HashRing::new(128);
        for i in 0..n {
            ring.add_node(&format!("node-{i}"));
        }
        let before = ownership(&ring, &keys);
        ring.add_node("joiner");
        let after = ownership(&ring, &keys);
        let mut moved = 0usize;
        for k in &keys {
            if before[k] != after[k] {
                moved += 1;
                assert_eq!(after[k], "joiner", "moved key {k} must land on the joiner");
            }
        }
        // ideal is K/(N+1); allow 2x slack for hash variance
        let bound = 2 * keys.len() / (n + 1);
        assert!(
            moved <= bound,
            "join at n={n} moved {moved} keys, bound {bound} (~2K/(N+1))"
        );
        assert!(moved > 0, "the joiner must take some keys");
    }
}

/// Node leave moves only the leaver's keys: every key the leaver did not own
/// keeps its owner.
#[test]
fn leave_moves_only_the_leavers_keys() {
    let keys = keys(4096);
    for n in 3..=6usize {
        let mut ring = HashRing::new(128);
        for i in 0..n {
            ring.add_node(&format!("node-{i}"));
        }
        let before = ownership(&ring, &keys);
        ring.remove_node("node-0");
        let after = ownership(&ring, &keys);
        for k in &keys {
            if before[k] != "node-0" {
                assert_eq!(before[k], after[k], "key {k} moved although its owner stayed");
            } else {
                assert_ne!(after[k], "node-0", "key {k} still owned by the departed node");
            }
        }
    }
}

/// Seeded random membership sequences: after any interleaving of joins and
/// leaves, placement equals a fresh ring built from the surviving member
/// set (history-independence), and each individual step only disrupts the
/// expected keys.
#[test]
fn random_membership_sequences_preserve_ring_invariants() {
    let keys = keys(1024);
    for seed in [1u64, 7, 42, 1234] {
        let mut rng = Rng::new(seed);
        let mut ring = HashRing::new(128);
        let mut live: BTreeSet<String> = BTreeSet::new();
        // start from a random initial population of 3..6 nodes
        for i in 0..(3 + rng.below(4)) {
            let id = format!("s{seed}-n{i}");
            ring.add_node(&id);
            live.insert(id);
        }
        let mut next_id = 100usize;
        for _step in 0..40 {
            let join = live.len() <= 1 || rng.below(2) == 0;
            let before = ownership(&ring, &keys);
            if join {
                let id = format!("s{seed}-n{next_id}");
                next_id += 1;
                ring.add_node(&id);
                live.insert(id.clone());
                let after = ownership(&ring, &keys);
                let moved = keys.iter().filter(|k| before[*k] != after[*k]).count();
                assert!(
                    moved <= 2 * keys.len() / live.len(),
                    "seed {seed}: join moved {moved} of {} keys across {} nodes",
                    keys.len(),
                    live.len()
                );
                for k in &keys {
                    if before[k] != after[k] {
                        assert_eq!(after[k], id);
                    }
                }
            } else {
                let victim = {
                    let idx = rng.below(live.len());
                    live.iter().nth(idx).expect("index in range").clone()
                };
                ring.remove_node(&victim);
                live.remove(&victim);
                let after = ownership(&ring, &keys);
                for k in &keys {
                    if before[k] != victim.as_str() {
                        assert_eq!(before[k], after[k], "seed {seed}: non-victim key moved");
                    }
                }
            }
            assert_eq!(ring.len(), live.len());
        }
        // history-independence: same member set, fresh ring, same placement
        let mut fresh = HashRing::new(128);
        for id in &live {
            fresh.add_node(id);
        }
        for k in &keys {
            assert_eq!(ring.primary(k), fresh.primary(k), "seed {seed}: history leaked");
            assert_eq!(ring.replicas(k, 2), fresh.replicas(k, 2));
        }
    }
}

/// Replica sets are distinct, ordered from the primary, and shrink gracefully
/// below R live nodes — the failover walk the router relies on.
#[test]
fn replica_sets_support_failover_walks() {
    let mut ring = HashRing::new(128);
    for i in 0..3 {
        ring.add_node(&format!("node-{i}"));
    }
    for k in keys(256) {
        let reps = ring.replicas(&k, 2);
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0], ring.primary(&k).unwrap());
        assert_ne!(reps[0], reps[1]);
        // asking for more replicas than nodes yields all nodes
        assert_eq!(ring.replicas(&k, 10).len(), 3);
    }
}
