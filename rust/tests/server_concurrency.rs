//! Serving-path integration tests: multi-worker bit-exactness on the int8
//! path, error-response propagation (no reply channel is ever abandoned),
//! bounded-queue backpressure, graceful shutdown draining, mixed-shape
//! rejection, and multi-deployment routing — the contracts behind the
//! paper's serving-side latency/throughput numbers.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};
use quant_trim::backends::{backend_by_name, CheckpointView, PtqOptions, RangeSource};
use quant_trim::coordinator::experiment::compile_serving_fleet;
use quant_trim::coordinator::server::{
    BatchModel, BatchPolicy, EngineModel, Server, ServerConfig, ServerDeployment,
};
use quant_trim::engine::{fp32_model, CompiledModel};
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::{synth, Rng};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// A synthetic int8 NPU deployment (hardware_d toolchain, per-channel
/// ties-even) on the seeded resnet-like graph — no artifacts needed.
fn int8_deployment() -> Arc<CompiledModel> {
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xCAFE);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let qstate = BTreeMap::new();
    let view =
        CheckpointView { graph: &sm.graph, params: &sm.params, bn: &sm.bn, qstate: &qstate };
    let be = backend_by_name("hardware_d").unwrap();
    let dep = be
        .compile(view, Precision::Int8, RangeSource::Calibration, &calib, PtqOptions::default())
        .expect("synthetic int8 compile");
    Arc::new(dep.model)
}

/// Echoes each request's first pixel after an optional delay.
struct SlowEcho {
    delay: Duration,
    batch: usize,
}

impl BatchModel for SlowEcho {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let n = images.shape[0];
        let sz: usize = images.shape[1..].iter().product();
        let mut out = Tensor::zeros(&[n, 1]);
        for (i, o) in out.data.iter_mut().enumerate() {
            *o = images.data[i * sz];
        }
        Ok(out)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }
}

#[test]
fn multi_worker_matches_single_worker_bit_exact_int8() {
    let model = int8_deployment();
    let images: Vec<Tensor> = {
        let mut rng = Rng::new(0x1337);
        (0..32).map(|_| Tensor::new(vec![3, 16, 16], rng.normal_vec(3 * 256, 1.0))).collect()
    };
    let run = |workers: usize| -> Vec<Vec<f32>> {
        let server = Server::start(
            vec![ServerDeployment {
                name: "npu".into(),
                model: Arc::new(EngineModel::new(model.clone(), 8)),
                fallbacks: Vec::new(),
            }],
            ServerConfig {
                workers,
                queue_depth: 64,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(2),
                    slo_margin: None,
                },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        // concurrent clients: 4 threads x 8 requests each
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let server = &server;
            let handles: Vec<_> = images
                .chunks(8)
                .map(|chunk| {
                    s.spawn(move || {
                        chunk
                            .iter()
                            .map(|im| {
                                let rx = server.submit_image(im.clone(), Some("npu")).unwrap();
                                rx.recv_timeout(RECV_TIMEOUT)
                                    .expect("every request must be answered")
                                    .result
                                    .expect("int8 deployment must not fail")
                            })
                            .collect::<Vec<Vec<f32>>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let stats = server.shutdown();
        assert_eq!(stats.served, 32);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.rejected, 0);
        outs
    };
    let single = run(1);
    let quad = run(4);
    assert_eq!(single, quad, "int8 serving must be bit-exact across worker counts");
}

struct ExplodingNpu;

impl BatchModel for ExplodingNpu {
    fn run_batch(&self, _images: &Tensor) -> Result<Tensor> {
        bail!("simulated NPU fault")
    }
    fn max_batch(&self) -> usize {
        4
    }
}

/// Regression: `server.rs` used to `continue` on model error, abandoning
/// every reply channel in the batch (clients blocked on `recv()` forever).
#[test]
fn model_errors_propagate_to_every_client() {
    let server = Server::single(
        ExplodingNpu,
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..10)
        .map(|i| server.submit_image(Tensor::full(&[1, 2, 2], i as f32), None).unwrap())
        .collect();
    for rx in &rxs {
        let resp = rx.recv_timeout(RECV_TIMEOUT).expect("error responses must still arrive");
        let err = resp.result.expect_err("model failure must surface as an error response");
        assert!(err.contains("simulated NPU fault"), "{err}");
    }
    let stats = server.shutdown();
    assert_eq!(stats.errors, 10);
    assert_eq!(stats.served, 0);
}

#[test]
fn backpressure_rejects_at_bounded_queue() {
    let server = Server::single(
        SlowEcho { delay: Duration::from_millis(30), batch: 1 },
        ServerConfig {
            workers: 1,
            queue_depth: 2,
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..40 {
        match server.submit_image(Tensor::full(&[1, 2, 2], i as f32), None) {
            Ok(rx) => accepted.push((i, rx)),
            Err(e) => {
                assert!(e.is_queue_full(), "only QueueFull expected while running");
                let req = e.into_request();
                assert_eq!(req.image.data[0], i as f32, "rejected request handed back intact");
                rejected += 1;
            }
        }
    }
    assert!(
        rejected > 0,
        "40 instant submissions against a depth-2 queue and a 30ms/batch worker must hit QueueFull"
    );
    for (i, rx) in &accepted {
        let resp = rx.recv_timeout(RECV_TIMEOUT).expect("accepted requests are never dropped");
        let logits = resp.result.expect("slow echo never fails");
        assert_eq!(logits[0], *i as f32);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, accepted.len());
    assert_eq!(stats.rejected, rejected);
    assert_eq!(stats.errors, 0);
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let server = Server::single(
        SlowEcho { delay: Duration::from_millis(20), batch: 2 },
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(5),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..8)
        .map(|i| server.submit_image(Tensor::full(&[1, 2, 2], i as f32), None).unwrap())
        .collect();
    // shut down immediately: everything already accepted must still be served
    let stats = server.shutdown();
    assert_eq!(stats.served, 8);
    assert_eq!(stats.errors, 0);
    for (i, rx) in rxs.iter().enumerate() {
        let resp = rx.try_recv().expect("shutdown() must drain every reply before returning");
        let logits = resp.result.expect("slow echo never fails");
        assert_eq!(logits[0], i as f32);
    }
}

#[test]
fn mixed_shape_rejected_by_declared_input_shape() {
    let sm = synth::resnet_like(16, 16);
    let model = Arc::new(fp32_model(sm.graph.clone(), sm.params.clone(), sm.bn.clone()));
    let server = Server::start(
        vec![ServerDeployment {
            name: "fp32".into(),
            model: Arc::new(EngineModel::new(model, 4)),
            fallbacks: Vec::new(),
        }],
        ServerConfig {
            workers: 1,
            queue_depth: 16,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let bad = server.submit_image(Tensor::zeros(&[3, 8, 8]), None).unwrap();
    let good = server.submit_image(Tensor::zeros(&[3, 16, 16]), None).unwrap();
    let resp = bad.recv_timeout(RECV_TIMEOUT).unwrap();
    let err = resp.result.expect_err("mis-shaped request must be rejected");
    assert!(err.contains("expected input shape"), "{err}");
    let resp = good.recv_timeout(RECV_TIMEOUT).unwrap();
    assert!(resp.result.is_ok(), "well-shaped request must still serve");
    let stats = server.shutdown();
    assert_eq!(stats.served, 1);
    assert_eq!(stats.errors, 1);
}

#[test]
fn mixed_shape_rejected_against_in_flight_batch() {
    // no declared input shape: the router falls back to screening against
    // the batch the request would join
    let server = Server::single(
        SlowEcho { delay: Duration::ZERO, batch: 4 },
        ServerConfig {
            workers: 1,
            queue_depth: 16,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(10),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let a = server.submit_image(Tensor::full(&[1, 2, 2], 1.0), None).unwrap();
    let b = server.submit_image(Tensor::full(&[2, 2, 2], 2.0), None).unwrap(); // wrong shape
    let c = server.submit_image(Tensor::full(&[1, 2, 2], 3.0), None).unwrap();
    let d = server.submit_image(Tensor::full(&[1, 2, 2], 4.0), None).unwrap();
    let e = server.submit_image(Tensor::full(&[1, 2, 2], 5.0), None).unwrap();
    let resp = b.recv_timeout(RECV_TIMEOUT).unwrap();
    let err = resp.result.expect_err("mismatched shape must be rejected");
    assert!(err.contains("batch shape"), "{err}");
    for (rx, want) in [(&a, 1.0f32), (&c, 3.0), (&d, 4.0), (&e, 5.0)] {
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        assert_eq!(resp.batch_size, 4, "the four matching requests form one full batch");
        let logits = resp.result.expect("matching requests must serve");
        assert_eq!(logits[0], want);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.errors, 1);
}

/// Scales each request's pixel sum by a per-deployment constant, so a
/// response proves which deployment executed it.
struct ScaleModel {
    k: f32,
}

impl BatchModel for ScaleModel {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        let n = images.shape[0];
        let sz: usize = images.shape[1..].iter().product();
        let mut out = Tensor::zeros(&[n, 1]);
        for (i, o) in out.data.iter_mut().enumerate() {
            *o = self.k * images.data[i * sz..(i + 1) * sz].iter().sum::<f32>();
        }
        Ok(out)
    }
    fn max_batch(&self) -> usize {
        4
    }
}

#[test]
fn router_maps_requests_to_named_deployments() {
    let server = Server::start(
        vec![
            ServerDeployment::new("npu_x2", ScaleModel { k: 2.0 }),
            ServerDeployment::new("npu_x10", ScaleModel { k: 10.0 }),
        ],
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut expect = Vec::new();
    for i in 0..12 {
        let (name, k) = if i % 2 == 0 { ("npu_x2", 2.0f32) } else { ("npu_x10", 10.0f32) };
        let rx = server.submit_image(Tensor::full(&[1, 2, 2], i as f32), Some(name)).unwrap();
        expect.push((rx, name, k * 4.0 * i as f32));
    }
    for (rx, name, want) in expect {
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        assert_eq!(resp.deployment, name);
        let logits = resp.result.expect("scale model never fails");
        assert_eq!(logits[0], want);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 12);
    assert_eq!(stats.errors, 0);
}

#[test]
fn serving_fleet_fronts_multiple_precisions() {
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xCA11B);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    // one server fronting two simulated NPUs at different precisions:
    // hardware_a (strict W8/A8) and hardware_b (W8/ABF16 hybrid)
    let fleet = compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &[("hardware_a", None, ActScaling::Static), ("hardware_b", None, ActScaling::Static)],
        &calib,
        4,
        None,
    )
    .unwrap();
    assert_eq!(fleet.len(), 2);
    let server = Server::start(
        fleet,
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let img = Tensor::new(vec![3, 16, 16], Rng::new(0xF00D).normal_vec(3 * 256, 1.0));
    let a = server.submit_image(img.clone(), Some("hardware_a")).unwrap();
    let b = server.submit_image(img.clone(), Some("hardware_b")).unwrap();
    let ra = a.recv_timeout(RECV_TIMEOUT).unwrap();
    let rb = b.recv_timeout(RECV_TIMEOUT).unwrap();
    assert_eq!(ra.deployment, "hardware_a");
    assert_eq!(rb.deployment, "hardware_b");
    let la = ra.result.expect("int8 deployment must serve");
    let lb = rb.result.expect("bf16 deployment must serve");
    assert_eq!(la.len(), 10);
    assert_eq!(lb.len(), 10);
    assert!(la.iter().chain(lb.iter()).all(|v| v.is_finite()));
    let stats = server.shutdown();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.errors, 0);
}

#[test]
fn serving_fleet_mixes_int4_and_int8_bit_widths() {
    // the same physical backend listed at both weight bit-widths: the fleet
    // compiler disambiguates the deployment names with @PREC suffixes and
    // the router serves each grid independently
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xCA114);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let fleet = compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &[
            ("hardware_d", Some(Precision::Int8), ActScaling::Static),
            ("hardware_d", Some(Precision::Int4), ActScaling::Static),
        ],
        &calib,
        4,
        None,
    )
    .unwrap();
    let names: Vec<&str> = fleet.iter().map(|d| d.name.as_str()).collect();
    assert_eq!(names, vec!["hardware_d@INT8", "hardware_d@INT4"]);
    let server = Server::start(
        fleet,
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let img = Tensor::new(vec![3, 16, 16], Rng::new(0xF00E).normal_vec(3 * 256, 1.0));
    let r8 = server.submit_image(img.clone(), Some("hardware_d@INT8")).unwrap();
    let r4 = server.submit_image(img.clone(), Some("hardware_d@INT4")).unwrap();
    let l8 = r8.recv_timeout(RECV_TIMEOUT).unwrap().result.expect("int8 serves");
    let l4 = r4.recv_timeout(RECV_TIMEOUT).unwrap().result.expect("int4 serves");
    assert_eq!(l8.len(), 10);
    assert_eq!(l4.len(), 10);
    assert!(l8.iter().chain(l4.iter()).all(|v| v.is_finite()));
    // the two grids really differ — int4 traffic is not silently int8
    assert_ne!(l8, l4, "int4 deployment must answer from the 16-level weight grid");
    let stats = server.shutdown();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.errors, 0);
}

#[test]
fn serving_fleet_mixes_static_and_dynamic_scaling() {
    // the same physical backend deployed with compile-time AND live-batch
    // activation ranges behind one router: the fleet compiler suffixes the
    // dynamic entry with @dyn and both variants serve the same traffic
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xCA115);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let fleet = compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &[
            ("hardware_d", Some(Precision::Int8), ActScaling::Static),
            ("hardware_d", Some(Precision::Int8), ActScaling::Dynamic),
        ],
        &calib,
        4,
        None,
    )
    .unwrap();
    let names: Vec<&str> = fleet.iter().map(|d| d.name.as_str()).collect();
    assert_eq!(names, vec!["hardware_d@INT8", "hardware_d@INT8@dyn"]);
    let server = Server::start(
        fleet,
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let img = Tensor::new(vec![3, 16, 16], Rng::new(0xF00F).normal_vec(3 * 256, 1.0));
    let rs = server.submit_image(img.clone(), Some("hardware_d@INT8")).unwrap();
    let rd = server.submit_image(img.clone(), Some("hardware_d@INT8@dyn")).unwrap();
    let ls = rs.recv_timeout(RECV_TIMEOUT).unwrap().result.expect("static serves");
    let ld = rd.recv_timeout(RECV_TIMEOUT).unwrap().result.expect("dynamic serves");
    assert_eq!(ls.len(), 10);
    assert_eq!(ld.len(), 10);
    assert!(ls.iter().chain(ld.iter()).all(|v| v.is_finite()));
    // live-batch ranges really differ from the calibrated ones
    assert_ne!(ls, ld, "dynamic deployment must answer from live-batch ranges");
    let stats = server.shutdown();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.errors, 0);
}
