//! Robustness contract of the native Quant-Trim trainer and the serving
//! hot-swap path:
//!
//! * kill-and-resume determinism — a run killed mid-epoch and resumed from
//!   its atomic checkpoint produces a byte-identical final checkpoint;
//! * non-finite-loss containment — an injected NaN step rolls back to the
//!   last epoch boundary with lambda/LR backoff, training completes, and
//!   the final checkpoint audits clean;
//! * corrupt-checkpoint fallback — a flipped byte in the newest checkpoint
//!   is caught by the file checksum and resume falls back one epoch;
//! * scale-inflation watchdog — an inflated weight channel triggers an
//!   early reverse-prune via the static audit pass;
//! * gradient correctness — the handwritten backward matches directional
//!   finite differences on the f32 path;
//! * audit-gated zero-downtime hot-swap — a live server swaps checkpoints
//!   without losing a request, post-swap responses are bit-exact against a
//!   directly-run instance of the candidate, and a NaN-weighted candidate
//!   is refused while the incumbent keeps serving.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::qtrain::{NativeTrainer, QtConfig, RunControls};
use quant_trim::coordinator::server::{
    EngineModel, Outcome, Server, ServerConfig, ServerDeployment,
};
use quant_trim::coordinator::TrainState;
use quant_trim::data::gen_cls_batch;
use quant_trim::engine::fp32_model;
use quant_trim::tensor::Tensor;
use quant_trim::testutil::{synth, Rng};

const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// Fresh per-test scratch directory under the system temp dir.
fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qt_train_{}_{}", name, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Tiny-but-real config: small enough for debug-mode CI, big enough that
/// the curriculum ramps and checkpoints span several epochs. The watchdog
/// is off by default here (its own test turns it on) so these tests
/// exercise exactly one robustness mechanism each.
fn tiny_cfg(epochs: usize, steps: usize) -> QtConfig {
    let mut cfg = QtConfig::tiny(epochs, steps);
    cfg.watchdog = false;
    cfg
}

// ---------------------------------------------------------------------------
// kill -9 and resume
// ---------------------------------------------------------------------------

/// A run aborted abruptly mid-epoch (no checkpoint, no cleanup — the moral
/// equivalent of `kill -9`) and resumed from its manifest must converge to
/// a final checkpoint that is BYTE-identical to an uninterrupted run's.
#[test]
fn kill_and_resume_reproduces_final_checkpoint_bit_exactly() {
    let cfg = tiny_cfg(4, 3);

    // Uninterrupted reference run.
    let dir_a = fresh_dir("resume_a");
    let sm = synth::resnet_like(8, 8);
    let mut full = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());
    let rep_a = full.train(&dir_a, RunControls::default()).expect("reference run");
    assert!(!rep_a.aborted);
    assert_eq!(rep_a.logs.len(), 4);
    let final_a = rep_a.final_checkpoint.expect("reference final checkpoint");

    // Killed run: epochs 0-1 checkpoint, epoch 2 dies after one step.
    let dir_b = fresh_dir("resume_b");
    let mut killed = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());
    let rep_kill = killed
        .train(&dir_b, RunControls { abort_after_steps: Some(7), ..Default::default() })
        .expect("aborted run still returns a report");
    assert!(rep_kill.aborted);
    assert_eq!(rep_kill.logs.len(), 2, "two epochs checkpointed before the kill");
    drop(killed); // the process is gone; only the files survive

    // Resume from disk and finish.
    let mut resumed = NativeTrainer::resume(sm.graph.clone(), cfg.clone(), &dir_b)
        .expect("resume parses manifest")
        .expect("manifest present after two checkpointed epochs");
    let rep_b = resumed.train(&dir_b, RunControls::default()).expect("resumed run");
    assert!(!rep_b.aborted);
    let first = rep_b.logs.first().expect("resumed run trains at least one epoch");
    assert_eq!(first.epoch, 2, "resume must not repeat completed epochs");
    let final_b = rep_b.final_checkpoint.expect("resumed final checkpoint");

    let bytes_a = std::fs::read(&final_a).expect("read reference checkpoint");
    let bytes_b = std::fs::read(&final_b).expect("read resumed checkpoint");
    assert_eq!(final_a.file_name(), final_b.file_name());
    assert!(
        bytes_a == bytes_b,
        "final checkpoints diverge after kill-and-resume ({} vs {} bytes)",
        bytes_a.len(),
        bytes_b.len()
    );
}

/// Resume is a no-op source of state when nothing has checkpointed yet.
#[test]
fn resume_on_empty_dir_reports_fresh_start() {
    let dir = fresh_dir("resume_empty");
    let sm = synth::resnet_like(8, 8);
    let got = NativeTrainer::resume(sm.graph.clone(), tiny_cfg(2, 2), &dir).expect("no manifest is not an error");
    assert!(got.is_none());
}

// ---------------------------------------------------------------------------
// corrupt checkpoint fallback
// ---------------------------------------------------------------------------

/// A flipped byte in the newest checkpoint must be caught by the file
/// checksum; resume falls back to the previous epoch instead of loading
/// garbage weights, and retraining repairs the corrupt file.
#[test]
fn corrupt_latest_checkpoint_falls_back_one_epoch() {
    let cfg = tiny_cfg(3, 2);
    let dir = fresh_dir("corrupt_fallback");
    let sm = synth::resnet_like(8, 8);
    let mut tr = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());
    let rep = tr.train(&dir, RunControls::default()).expect("seed run");
    let latest = rep.final_checkpoint.expect("final checkpoint");
    assert!(latest.to_string_lossy().contains("ckpt_e0002"));

    let mut bytes = std::fs::read(&latest).expect("read latest");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&latest, &bytes).expect("plant corruption");
    assert!(Checkpoint::load(&latest).is_err(), "checksum must reject the corrupt file");

    let mut resumed = NativeTrainer::resume(sm.graph.clone(), cfg.clone(), &dir)
        .expect("resume survives a corrupt manifest target")
        .expect("earlier epochs still load");
    let rep2 = resumed.train(&dir, RunControls::default()).expect("repair run");
    assert_eq!(rep2.logs.len(), 1, "exactly the lost epoch is retrained");
    assert_eq!(rep2.logs[0].epoch, 2);
    let repaired = rep2.final_checkpoint.expect("repaired checkpoint");
    assert_eq!(repaired, latest);
    Checkpoint::load(&repaired).expect("repaired checkpoint loads cleanly");
}

// ---------------------------------------------------------------------------
// non-finite containment
// ---------------------------------------------------------------------------

/// An injected NaN loss must never reach the optimizer: the step is
/// refused, state rolls back to the last epoch boundary, lambda/LR back
/// off, and the run still completes with a clean, auditable checkpoint.
#[test]
fn nan_step_rolls_back_and_training_still_completes() {
    let cfg = tiny_cfg(3, 3);
    let dir = fresh_dir("nan_rollback");
    let sm = synth::resnet_like(8, 8);
    let mut tr = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());

    let mut fired = false;
    let mut fault = |epoch: usize, step: usize| {
        if !fired && epoch == 1 && step == 1 {
            fired = true;
            true
        } else {
            false
        }
    };
    let rep = tr
        .train(&dir, RunControls { fault: Some(&mut fault), ..Default::default() })
        .expect("training survives the injected fault");

    assert!(fired, "fault hook must have fired");
    assert!(!rep.aborted);
    assert_eq!(rep.rollbacks, 1);
    assert_eq!(tr.rollbacks(), 1);
    assert_eq!(rep.logs.len(), 3, "every epoch still completes");
    let ep1 = &rep.logs[1];
    assert_eq!(ep1.nonfinite_steps, 1, "the poisoned step is visible in the epoch log");
    assert!(ep1.loss.is_finite(), "the retried epoch's mean excludes the poisoned step");
    for log in &rep.logs {
        assert!(log.loss.is_finite() && log.acc.is_finite());
    }

    // The final checkpoint must be numerically sound end to end: load,
    // restore, compile through the real deployment path, audit, run.
    let path = rep.final_checkpoint.expect("final checkpoint");
    let ck = Checkpoint::load(&path).expect("final checkpoint loads");
    let state = TrainState::from_checkpoint(&ck);
    let model = fp32_model(sm.graph.clone(), state.params.clone(), state.bn.clone());
    let report = model.audit(None).expect("audit runs");
    assert!(
        !report.has_errors(),
        "post-rollback checkpoint must audit ERROR-free: {:?}",
        report.findings
    );
    let batch = gen_cls_batch(cfg.data, 2, 0xF00D);
    let out = model.run(&batch.images).expect("restored model runs");
    assert!(out[0].data.iter().all(|v| v.is_finite()), "restored logits are finite");
}

/// A fault that poisons every attempt must abort with a diverged error
/// after `max_rollbacks` instead of looping forever.
#[test]
fn persistent_nan_fault_aborts_after_max_rollbacks() {
    let mut cfg = tiny_cfg(2, 2);
    cfg.max_rollbacks = 3;
    let dir = fresh_dir("nan_diverge");
    let sm = synth::resnet_like(8, 8);
    let mut tr = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg);
    let mut fault = |_: usize, _: usize| true;
    let err = tr
        .train(&dir, RunControls { fault: Some(&mut fault), ..Default::default() })
        .expect_err("an unrecoverable fault must surface as an error");
    assert!(err.to_string().contains("diverged"), "unexpected error: {err:#}");
}

// ---------------------------------------------------------------------------
// scale-inflation watchdog
// ---------------------------------------------------------------------------

/// Inflating one output channel of a conv weight (the paper's outlier-
/// driven scale-inflation failure) must trip the in-training audit
/// watchdog, which reverse-prunes the outlier early instead of letting it
/// dictate the deployment grid.
#[test]
fn watchdog_reverse_prunes_on_scale_inflation() {
    let sm = synth::resnet_like(8, 8);
    let mut params = sm.params.clone();
    let w = params.get_mut("c2.w").expect("c2.w exists");
    let row = w.data.len() / w.shape[0];
    for v in &mut w.data[..row] {
        *v *= 100.0; // channel 0 now dwarfs every other channel's scale
    }
    let inflated_max = w.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));

    let mut cfg = QtConfig::tiny(1, 2);
    cfg.watchdog = true;
    let dir = fresh_dir("watchdog");
    let mut tr = NativeTrainer::new(sm.graph.clone(), params, sm.bn.clone(), cfg);
    let rep = tr.train(&dir, RunControls::default()).expect("watchdog run");

    assert!(rep.watchdog_prunes >= 1, "watchdog must fire on the inflated channel");
    assert!(rep.logs[0].watchdog_pruned);
    let pruned_max = tr
        .state
        .params
        .get("c2.w")
        .expect("c2.w survives")
        .data
        .iter()
        .fold(0.0f32, |a, &v| a.max(v.abs()));
    assert!(
        pruned_max < inflated_max,
        "reverse prune must pull the outlier channel in ({pruned_max} vs {inflated_max})"
    );
}

/// Healthy seeded weights must NOT trip the watchdog — it is an outlier
/// detector, not a per-epoch tax on every run.
#[test]
fn watchdog_stays_quiet_on_healthy_weights() {
    let sm = synth::resnet_like(8, 8);
    let mut cfg = QtConfig::tiny(1, 2);
    cfg.watchdog = true;
    let dir = fresh_dir("watchdog_quiet");
    let mut tr = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg);
    let rep = tr.train(&dir, RunControls::default()).expect("healthy run");
    assert_eq!(rep.watchdog_prunes, 0, "no inflation, no watchdog prune");
}

// ---------------------------------------------------------------------------
// gradient correctness
// ---------------------------------------------------------------------------

/// Directional finite differences on the plain f32 path: for a fixed
/// random direction d over one parameter tensor,
/// `(L(w + h d) - L(w - h d)) / 2h` must match `<grad, d>`.
#[test]
fn backward_matches_directional_finite_differences() {
    let sm = synth::resnet_like(8, 8);
    let mut cfg = tiny_cfg(1, 1);
    cfg.quant_trim = false; // exact f32 path: no STE, no fake quant
    let mut tr = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());
    let batch = gen_cls_batch(cfg.data, 4, 0xBEEF);

    let analytic = tr.loss_and_grads(&batch, 0.0).expect("analytic grads");
    let mut rng = Rng::new(0x6AD5);
    let h = 5e-3f32; // large enough to clear f32 loss noise, small enough
                     // that relu/hswish kink crossings stay second-order
    for key in ["c1.w", "c3.w", "cdw.w", "head.w", "head.b", "b2.gamma"] {
        let n = tr.state.params.get(key).expect("param exists").len();
        let dir: Vec<f32> = rng.normal_vec(n, 1.0);
        let base = tr.state.params.get(key).unwrap().data.clone();

        let loss_at = |sign: f32, tr: &mut NativeTrainer| -> f32 {
            let t = tr.state.params.get_mut(key).unwrap();
            for (v, (&b, &d)) in t.data.iter_mut().zip(base.iter().zip(dir.iter())) {
                *v = b + sign * h * d;
            }
            tr.loss_and_grads(&batch, 0.0).expect("perturbed forward").loss
        };
        let lp = loss_at(1.0, &mut tr);
        let lm = loss_at(-1.0, &mut tr);
        tr.state.params.get_mut(key).unwrap().data.copy_from_slice(&base);

        let numeric = f64::from(lp - lm) / (2.0 * f64::from(h));
        let ana: f64 = analytic
            .grads
            .get(key)
            .unwrap_or_else(|| panic!("no gradient for {key}"))
            .data
            .iter()
            .zip(dir.iter())
            .map(|(&g, &d)| f64::from(g) * f64::from(d))
            .sum();
        let tol = 3e-3 + 0.1 * ana.abs();
        assert!(
            (numeric - ana).abs() <= tol,
            "{key}: directional derivative mismatch numeric={numeric:.6} analytic={ana:.6}"
        );
    }
}

/// End-to-end smoke of the full Quant-Trim loop: every epoch logs finite
/// loss/accuracy, held-out evaluation through the compiled deployment path
/// is finite, and the scheduled reverse prune fires on schedule.
#[test]
fn quant_trim_run_trains_and_evaluates_finite() {
    let cfg = tiny_cfg(3, 3);
    let dir = fresh_dir("qt_smoke");
    let sm = synth::resnet_like(8, 8);
    let mut tr = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());
    let rep = tr.train(&dir, RunControls::default()).expect("training runs");
    assert_eq!(rep.logs.len(), 3);
    assert!(rep.logs.iter().any(|l| l.pruned), "the compressed curriculum schedules a prune");
    for log in &rep.logs {
        assert!(log.loss.is_finite(), "epoch {} loss non-finite", log.epoch);
        assert!((0.0..=1.0).contains(&log.acc), "epoch {} acc out of range", log.epoch);
        assert_eq!(log.nonfinite_steps, 0);
    }
    let (val_loss, val_acc) = tr.evaluate(2).expect("held-out eval");
    assert!(val_loss.is_finite());
    assert!((0.0..=1.0).contains(&val_acc));
}

// ---------------------------------------------------------------------------
// audit-gated zero-downtime hot-swap
// ---------------------------------------------------------------------------

/// Hot-swapping a checkpoint into a live server must lose zero accepted
/// requests; once the swap lands, responses are bit-exact against a
/// directly-run instance of the very same candidate model.
#[test]
fn hot_swap_under_live_traffic_loses_nothing_and_is_bit_exact() {
    let sm = synth::resnet_like(8, 8);
    let model_a = Arc::new(fp32_model(sm.graph.clone(), sm.params.clone(), sm.bn.clone()));
    // Candidate: same architecture, visibly different weights.
    let params_b: BTreeMap<String, Tensor> =
        sm.params.iter().map(|(k, t)| (k.clone(), t.map(|v| v * 0.8))).collect();
    let model_b = Arc::new(fp32_model(sm.graph.clone(), params_b, sm.bn.clone()));

    let server = Server::start(
        vec![ServerDeployment::new("qt", EngineModel::new(model_a.clone(), 8))],
        ServerConfig { workers: 2, ..Default::default() },
    )
    .expect("server starts");

    const THREADS: usize = 3;
    const PER_THREAD: usize = 40;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let server = &server;
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(0x10AD + t as u64);
                let mut served = 0usize;
                for _ in 0..PER_THREAD {
                    let img = Tensor::new(vec![3, 8, 8], rng.normal_vec(3 * 64, 1.0));
                    let rx = server
                        .submit_image(img, Some("qt"))
                        .unwrap_or_else(|_| panic!("submit refused under light load"));
                    let resp = rx.recv_timeout(RECV_TIMEOUT).expect("response arrives");
                    assert_eq!(resp.outcome, Outcome::Served, "{:?}", resp.result);
                    assert!(resp.result.is_ok());
                    served += 1;
                }
                served
            }));
        }
        // Swap mid-flight: traffic before the swap runs on A, after on B,
        // and nothing in between is dropped.
        std::thread::sleep(Duration::from_millis(10));
        let report = server.swap_model("qt", EngineModel::new(model_b.clone(), 8)).expect("audit-clean swap lands");
        assert!(!report.has_errors());
        let total: usize = handles.into_iter().map(|h| h.join().expect("submitter")).sum();
        assert_eq!(total, THREADS * PER_THREAD, "every accepted request was answered");
    });

    // Post-swap determinism: the served logits equal running the candidate
    // model directly, bit for bit.
    let mut rng = Rng::new(0x0B5E);
    let probe = rng.normal_vec(3 * 64, 1.0);
    let rx = server
        .submit_image(Tensor::new(vec![3, 8, 8], probe.clone()), Some("qt"))
        .unwrap_or_else(|_| panic!("probe submit"));
    let resp = rx.recv_timeout(RECV_TIMEOUT).expect("probe response");
    let served = resp.result.expect("probe served");
    let direct = model_b.run(&Tensor::new(vec![1, 3, 8, 8], probe)).expect("direct run");
    assert_eq!(served, direct[0].data, "post-swap responses must be bit-exact vs the candidate");

    let stats = server.shutdown();
    assert_eq!(stats.errors, 0, "zero requests lost or errored across the swap");
    assert_eq!(stats.served, THREADS * PER_THREAD + 1);
    assert_eq!(stats.model_swaps, 1);
}

/// A candidate that fails the static audit (NaN weights here) must be
/// refused while the incumbent keeps serving — a bad checkpoint can never
/// take down a healthy deployment.
#[test]
fn audit_failing_candidate_is_refused_and_old_model_keeps_serving() {
    let sm = synth::resnet_like(8, 8);
    let model_a = Arc::new(fp32_model(sm.graph.clone(), sm.params.clone(), sm.bn.clone()));
    let mut params_bad = sm.params.clone();
    params_bad.get_mut("head.w").expect("head.w").data[0] = f32::NAN;
    let model_bad = fp32_model(sm.graph.clone(), params_bad, sm.bn.clone());

    let server = Server::start(
        vec![ServerDeployment::new("qt", EngineModel::new(model_a.clone(), 8))],
        ServerConfig { workers: 1, ..Default::default() },
    )
    .expect("server starts");

    let err = server
        .swap_model("qt", EngineModel::new(Arc::new(model_bad), 8))
        .expect_err("NaN-weighted candidate must be refused");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("NONFINITE_PARAM") || msg.to_lowercase().contains("refused") || msg.contains("ERROR"),
        "refusal should cite the audit: {msg}"
    );

    // The incumbent still serves, bit-exact.
    let mut rng = Rng::new(0x5AFE);
    let probe = rng.normal_vec(3 * 64, 1.0);
    let rx = server
        .submit_image(Tensor::new(vec![3, 8, 8], probe.clone()), Some("qt"))
        .unwrap_or_else(|_| panic!("probe submit"));
    let resp = rx.recv_timeout(RECV_TIMEOUT).expect("probe response");
    assert_eq!(resp.outcome, Outcome::Served);
    let direct = model_a.run(&Tensor::new(vec![1, 3, 8, 8], probe)).expect("direct run");
    assert_eq!(resp.result.expect("served"), direct[0].data);

    let stats = server.shutdown();
    assert_eq!(stats.model_swaps, 0, "a refused candidate must not count as a swap");
    assert_eq!(stats.errors, 0);
}

/// Unknown deployments are a swap error, not a panic or a silent no-op.
#[test]
fn swap_on_unknown_deployment_errors() {
    let sm = synth::resnet_like(8, 8);
    let model = Arc::new(fp32_model(sm.graph.clone(), sm.params.clone(), sm.bn.clone()));
    let server = Server::start(
        vec![ServerDeployment::new("qt", EngineModel::new(model.clone(), 4))],
        ServerConfig { workers: 1, ..Default::default() },
    )
    .expect("server starts");
    assert!(server.swap_model("nope", EngineModel::new(model, 4)).is_err());
    let stats = server.shutdown();
    assert_eq!(stats.model_swaps, 0);
}
