//! Diagnostic: layerwise SNR of a backend's INT8 deployment vs the FP32
//! reference, on the init or a freshly-trained checkpoint. Used during the
//! perf/fidelity pass; kept as a troubleshooting tool.
//!
//!   cargo run --release --example debug_int8 -- [--train] [--qat]

use std::collections::HashMap;

use quant_trim::backends::{backend_by_name, CheckpointView, PtqOptions, RangeSource};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::{artifacts_dir, train_with_validation, Task};
use quant_trim::coordinator::{Curriculum, TrainConfig, TrainState};
use quant_trim::data::{gen_cls_batch, ClsSpec};
use quant_trim::perfmodel::Precision;
use quant_trim::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let do_train = std::env::args().any(|a| a == "--train");
    let qat = std::env::args().any(|a| a == "--qat");
    let dir = artifacts_dir()?;
    let task = ClsSpec::cifar10();

    let state = if do_train {
        let rt = Runtime::cpu()?;
        let cur = Curriculum::cifar().scaled_to(8, 100);
        let cfg = TrainConfig::quant_trim(8, 10, cur);
        let (tr, _) =
            train_with_validation(&rt, &dir, "resnet18_c10", cfg, Task::Cls(task), 0, false)?;
        tr.state
    } else {
        TrainState::from_checkpoint(&Checkpoint::load(dir.join("resnet18_c10.init.qtckpt"))?)
    };
    let graph = quant_trim::qir::Graph::load(dir.join("resnet18_c10.qir"))?;
    let calib: Vec<_> = (0..4).map(|i| gen_cls_batch(task, 16, 0xCA11B + i).images).collect();
    let be = backend_by_name("hardware_d").unwrap();
    let view = CheckpointView {
        graph: &graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    let src = if qat { RangeSource::QatScales } else { RangeSource::Calibration };
    let dep = be.compile(view, Precision::Int8, src, &calib, PtqOptions::default())?;
    let ref_folded = quant_trim::engine::fp32_model(
        dep.model.graph.clone(),
        dep.model.params.clone(),
        Default::default(),
    );
    let b = gen_cls_batch(task, 16, 0xE0A1);
    let mut reff: HashMap<String, Vec<f32>> = HashMap::new();
    ref_folded.run_observe(&b.images, &mut |n: &str, t: &quant_trim::tensor::Tensor| {
        reff.insert(n.to_string(), t.data.clone());
    })?;
    dep.model.run_observe(&b.images, &mut |n: &str, t: &quant_trim::tensor::Tensor| {
        if let Some(r) = reff.get(n) {
            let snr = quant_trim::metrics::snr_db(r, &t.data);
            let range = dep.model.act_ranges.get(n);
            let rmax = r.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            println!(
                "{n:<16} snr {snr:>8.2} dB   |ref|max {rmax:>8.2}   range {:?}",
                range.map(|r| (format!("{:.2}", r.0), format!("{:.2}", r.1)))
            );
        }
    })?;
    Ok(())
}
