//! End-to-end driver (DESIGN.md §5): trains a model with the full Quant-Trim
//! curriculum **through the Rust coordinator executing AOT HLO train steps**
//! (Python never runs), logs the training-dynamics curve (paper Figs 4/5/10),
//! optionally dumps the weight distribution shift (Fig 2), then deploys
//! QT-vs-MAP on INT backends and prints the Table 1/2-style rows.
//!
//!   cargo run --release --example train_cifar -- \
//!       --model resnet18 --epochs 20 --steps 20 [--task seg] [--fig2]

use anyhow::Result;

use quant_trim::backends::{backend_by_name, PtqOptions, RangeSource};
use quant_trim::coordinator::experiment::{
    artifacts_dir, deploy_and_eval, reference_metrics, train_with_validation, Task,
};
use quant_trim::coordinator::{Curriculum, TrainConfig};
use quant_trim::data::{ClsSpec, SegSpec};
use quant_trim::metrics::dist_summary;
use quant_trim::perfmodel::Precision;
use quant_trim::runtime::Runtime;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

fn main() -> Result<()> {
    let model = arg("--model", "resnet18");
    let epochs: usize = arg("--epochs", "20").parse()?;
    let steps: usize = arg("--steps", "20").parse()?;
    let task_name = arg("--task", "cls");
    let dir = artifacts_dir()?;
    let rt = Runtime::cpu()?;

    let (task, base_cur) = if task_name == "seg" {
        (Task::Seg(SegSpec::coco_like()), Curriculum::seg())
    } else if model == "vit" {
        (Task::Cls(ClsSpec { classes: 100, image: 32, outlier_p: 0.002 }), Curriculum::transformer())
    } else {
        let classes = if model.ends_with("c10") { 10 } else { 100 };
        (Task::Cls(ClsSpec { classes, image: 32, outlier_p: 0.002 }), Curriculum::cifar())
    };
    // compress the paper's 100-epoch curriculum to this run's budget
    let cur = base_cur.scaled_to(epochs, 100);

    println!("=== Quant-Trim training: {model} ({epochs} epochs x {steps} steps) ===");
    println!("curriculum: E_w={} E_f={} H={} p_clip={}", cur.e_w, cur.e_f, cur.horizon, cur.p_clip);

    let fig2_probe = |state: &quant_trim::coordinator::TrainState, label: &str| {
        let mut all: Vec<f32> = Vec::new();
        for (k, t) in &state.params {
            if k.ends_with(".w") {
                all.extend_from_slice(&t.data);
            }
        }
        let d = dist_summary(&all);
        println!(
            "[fig2] {label}: |w| p50={:.4} p99={:.4} p99.9={:.4} max={:.4} tail_ratio={:.2} kurtosis={:.2}",
            d.p50, d.p99, d.p999, d.max, d.tail_ratio, d.kurtosis
        );
    };

    // ---- Quant-Trim run (Figs 4/5: expect a dip at the ramp, then recovery)
    let cfg_qt = TrainConfig { base_lr: 3e-4, ..TrainConfig::quant_trim(epochs, steps, cur) };
    let (tr_qt, logs_qt) = train_with_validation(&rt, &dir, &model, cfg_qt, task, 4, true)?;
    if flag("--fig2") {
        fig2_probe(&tr_qt.state, "after quant-trim");
    }

    // ---- MAP baseline
    println!("--- MAP baseline ---");
    let cfg_map = TrainConfig { base_lr: 3e-4, ..TrainConfig::map_baseline(epochs, steps, cur) };
    let (tr_map, logs_map) = train_with_validation(&rt, &dir, &model, cfg_map, task, 4, true)?;
    if flag("--fig2") {
        fig2_probe(&tr_map.state, "after MAP");
    }

    // training-dynamics series (Fig 4/5/10 data)
    println!("\n[curve] epoch lambda qt_loss qt_val map_loss map_val");
    for (a, b) in logs_qt.iter().zip(logs_map.iter()) {
        println!(
            "[curve] {:>3} {:.3} {:.4} {:.3} {:.4} {:.3}",
            a.epoch,
            a.lam,
            a.loss,
            a.val_metric.unwrap_or(f64::NAN),
            b.loss,
            b.val_metric.unwrap_or(f64::NAN),
        );
    }

    if task_name == "seg" {
        println!("(segmentation run: deployment tables use classification models)");
        return Ok(());
    }

    // ---- deploy QT vs MAP on INT backends (Tables 1/2 shape)
    let graph = quant_trim::qir::Graph::load(dir.join(format!("{model}.qir")))?;
    let eval: Vec<_> = (0..8).map(|i| task.batch(64, 0x5EED_0000 + i)).collect();
    let calib: Vec<_> = (0..4).map(|i| task.batch(16, 0xCA11B_00 + i).images).collect();

    for (bname, prec) in [("hardware_b", Precision::Bf16), ("hardware_d", Precision::Int8)] {
        let be = backend_by_name(bname).unwrap();
        println!("\n=== {} ({}) — Table 1/2 analogue ===", bname, prec.label());
        println!(
            "{:<12} {:>14} {:>14} {:>9} {:>17} {:>17}",
            "method", "Top-1 (FP32)", "Top-5 (FP32)", "MSE", "Brier (FP32)", "ECE (FP32)"
        );
        for (label, state, src) in [
            ("Quant-Trim", &tr_qt.state, RangeSource::QatScales),
            ("MAP", &tr_map.state, RangeSource::Calibration),
        ] {
            let m = deploy_and_eval(
                &be,
                &graph,
                state,
                prec,
                src,
                PtqOptions::default(),
                &calib,
                &eval,
            )?;
            let (rt1, rt5, rb, re) = reference_metrics(&graph, state, &eval)?;
            println!(
                "{:<12} {:>6.2} ({:>5.2}) {:>6.2} ({:>5.2}) {:>9.5} {:>8.5} ({:.5}) {:>8.5} ({:.5})",
                label,
                m.top1 * 100.0,
                rt1 * 100.0,
                m.top5 * 100.0,
                rt5 * 100.0,
                m.logit_mse,
                m.brier,
                rb,
                m.ece,
                re
            );
        }
    }
    // persist checkpoints for downstream examples (deploy_matrix etc.)
    let out_qt = dir.join(format!("{model}.trained_qt.qtckpt"));
    let out_map = dir.join(format!("{model}.trained_map.qtckpt"));
    tr_qt.state.to_checkpoint().save(&out_qt)?;
    tr_map.state.to_checkpoint().save(&out_map)?;
    println!("\nsaved {} and {}", out_qt.display(), out_map.display());
    Ok(())
}
