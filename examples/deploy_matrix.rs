//! Cross-backend deployment matrix (paper Tables 1-3): deploy Quant-Trim and
//! MAP checkpoints across the whole simulated fleet and every supported
//! precision; report Top-1/Top-5/logit-MSE/Brier/ECE/SNR per cell, plus the
//! Table 3 SNR comparison (QT calibration-only vs MAP + Equalization +
//! AdaRound).
//!
//! Uses checkpoints saved by `train_cifar` if present; otherwise trains a
//! short run first.
//!
//!   cargo run --release --example deploy_matrix -- [--model resnet18] [--epochs 12]

use anyhow::Result;

use quant_trim::backends::{all_backends, PtqOptions, RangeSource};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::{
    artifacts_dir, deploy_and_eval, train_with_validation, Task,
};
use quant_trim::coordinator::{Curriculum, TrainConfig, TrainState};
use quant_trim::data::ClsSpec;
use quant_trim::runtime::Runtime;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    let model = arg("--model", "resnet18");
    let epochs: usize = arg("--epochs", "12").parse()?;
    let dir = artifacts_dir()?;
    let rt = Runtime::cpu()?;
    let classes = if model.ends_with("c10") { 10 } else { 100 };
    let task = Task::Cls(ClsSpec { classes, image: 32, outlier_p: 0.002 });

    // obtain QT + MAP checkpoints (reuse train_cifar outputs when available)
    let load_or_train = |qt: bool| -> Result<TrainState> {
        let suffix = if qt { "qt" } else { "map" };
        let path = dir.join(format!("{model}.trained_{suffix}.qtckpt"));
        if path.exists() {
            println!("using cached checkpoint {}", path.display());
            return Ok(TrainState::from_checkpoint(&Checkpoint::load(path)?));
        }
        let cur = Curriculum::cifar().scaled_to(epochs, 100);
        let cfg = if qt {
            TrainConfig::quant_trim(epochs, 16, cur)
        } else {
            TrainConfig::map_baseline(epochs, 16, cur)
        };
        println!("training {} checkpoint ({epochs} epochs)...", if qt { "QT" } else { "MAP" });
        let (tr, _) = train_with_validation(&rt, &dir, &model, cfg, task, 0, false)?;
        tr.state.to_checkpoint().save(&path)?;
        Ok(tr.state)
    };
    let qt_state = load_or_train(true)?;
    let map_state = load_or_train(false)?;

    let graph = quant_trim::qir::Graph::load(dir.join(format!("{model}.qir")))?;
    let eval: Vec<_> = (0..8).map(|i| task.batch(64, 0x5EED_0000 + i)).collect();
    let calib: Vec<_> = (0..4).map(|i| task.batch(16, 0xCA11B_00 + i).images).collect();

    println!(
        "\n=== Deployment matrix: {} — every backend x precision x method ===",
        model
    );
    println!(
        "{:<18} {:<5} {:<11} {:>6} {:>6} {:>9} {:>8} {:>8} {:>8} {:>9} {:>4}",
        "backend", "prec", "method", "Top-1", "Top-5", "logitMSE", "Brier", "ECE", "SNRdB", "estFPS", "fb"
    );
    for be in all_backends() {
        for prec in be.precisions.clone() {
            for (label, state, src) in [
                ("Quant-Trim", &qt_state, RangeSource::QatScales),
                ("MAP", &map_state, RangeSource::Calibration),
            ] {
                let res = deploy_and_eval(
                    &be,
                    &graph,
                    state,
                    prec,
                    src,
                    PtqOptions::default(),
                    &calib,
                    &eval,
                );
                match res {
                    Ok(m) => println!(
                        "{:<18} {:<5} {:<11} {:>6.2} {:>6.2} {:>9.5} {:>8.5} {:>8.5} {:>8.2} {:>9.0} {:>4}",
                        be.name,
                        prec.label(),
                        label,
                        m.top1 * 100.0,
                        m.top5 * 100.0,
                        m.logit_mse,
                        m.brier,
                        m.ece,
                        m.snr_db,
                        m.fps_modelled,
                        m.fallback_ops
                    ),
                    Err(e) => println!(
                        "{:<18} {:<5} {:<11} unsupported: {e}",
                        be.name,
                        prec.label(),
                        label
                    ),
                }
            }
        }
    }

    // === Table 3: SNR on Hardware A ===
    // Quant-Trim, calibration only  vs  MAP + Equalization + AdaRound
    println!("\n=== Table 3 analogue: output-layer SNR on hardware_a (A8W8) ===");
    let ha = all_backends().into_iter().find(|b| b.name == "hardware_a").unwrap();
    let qt = deploy_and_eval(
        &ha,
        &graph,
        &qt_state,
        quant_trim::perfmodel::Precision::Int8,
        RangeSource::Calibration, // calibration ONLY — no QAT scales, no extras
        PtqOptions::default(),
        &calib,
        &eval,
    )?;
    let map_eq_ada = deploy_and_eval(
        &ha,
        &graph,
        &map_state,
        quant_trim::perfmodel::Precision::Int8,
        RangeSource::Calibration,
        PtqOptions { equalization: true, adaround: true },
        &calib,
        &eval,
    )?;
    println!("{:<42} {:>8}", "method", "SNR (dB)");
    println!("{:<42} {:>8.2}", "Quant-Trim (calibration only)", qt.snr_db);
    println!("{:<42} {:>8.2}", "MAP baseline (Equalization + AdaRound)", map_eq_ada.snr_db);
    println!(
        "\npaper shape: QT calib-only ({:.1} dB) > MAP+EQ+AdaRound ({:.1} dB): {}",
        qt.snr_db,
        map_eq_ada.snr_db,
        if qt.snr_db > map_eq_ada.snr_db { "REPRODUCED" } else { "NOT reproduced" }
    );
    Ok(())
}
