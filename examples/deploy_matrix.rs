//! Cross-backend deployment matrix (paper Tables 1-3): deploy Quant-Trim and
//! MAP checkpoints across the whole simulated fleet and every supported
//! precision — including the sub-byte INT4 weight path, requested on EVERY
//! backend so the matrix shows both native W4/A8 cells and the
//! fallback-to-INT8 cells of devices without int4 kernels, and including the
//! paper's **static-vs-dynamic activation scaling** axis at the integer
//! precisions (dynamic requested on every backend too; parts without runtime
//! range support print the `dyn→static` fallback cell); report
//! Top-1/Top-5/logit-MSE/Brier/ECE/SNR per cell, plus the Table 3 SNR
//! comparison (QT calibration-only vs MAP + Equalization + AdaRound).
//!
//! Uses checkpoints saved by `train_cifar` if present; otherwise trains a
//! short run first.
//!
//!   cargo run --release --example deploy_matrix -- [--model resnet18] [--epochs 12]
//!
//! CI smoke mode (no artifacts, no PJRT, no training — synthetic seeded
//! checkpoint, whole fleet × precision × bit-width in seconds, table written
//! to DEPLOY_MATRIX.txt for artifact upload):
//!
//!   cargo run --release --example deploy_matrix -- --smoke

use std::fmt::Write as _;

use anyhow::Result;

use quant_trim::backends::{all_backends, BackendSpec, PtqOptions, RangeSource};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::{
    artifacts_dir, deploy_and_eval, deploy_and_eval_scaled, synthetic_state,
    train_with_validation, Task,
};
use quant_trim::coordinator::{Curriculum, TrainConfig, TrainState};
use quant_trim::data::{Batch, ClsSpec};
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::qir::Graph;
use quant_trim::runtime::Runtime;
use quant_trim::tensor::Tensor;
use quant_trim::testutil::synth;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

/// Precisions to request on a backend: everything it lists, plus an explicit
/// INT4 request when it has no native int4 (to exercise the INT8 fallback
/// row — the deployment matrix shows WHERE sub-byte support exists).
fn requested_precisions(be: &BackendSpec) -> Vec<Precision> {
    let mut precs = be.precisions.clone();
    if !precs.contains(&Precision::Int4) && precs.contains(&Precision::Int8) {
        precs.push(Precision::Int4);
    }
    precs
}

/// Activation-scaling modes to request at a precision: integer deployments
/// get the full static-vs-dynamic comparison (dynamic is requested on EVERY
/// backend — parts without runtime range support show the fallback-to-static
/// cell, exactly like the INT4→INT8 column); float-activation deployments
/// have no requantization points, so only static is meaningful.
fn requested_scalings(prec: Precision) -> Vec<ActScaling> {
    match prec {
        Precision::Int8 | Precision::Int4 => vec![ActScaling::Static, ActScaling::Dynamic],
        _ => vec![ActScaling::Static],
    }
}

const HEADER_FMT: &str =
    "backend            prec        act         method          Top-1  Top-5  logitMSE    Brier      ECE    SNRdB    estFPS   fb";

/// One backend × precision × scaling × checkpoint row, appended to `table`.
#[allow(clippy::too_many_arguments)]
fn matrix_row(
    table: &mut String,
    be: &BackendSpec,
    graph: &Graph,
    state: &TrainState,
    prec: Precision,
    scaling: ActScaling,
    label: &str,
    src: RangeSource,
    calib: &[Tensor],
    eval: &[Batch],
) {
    let res = deploy_and_eval_scaled(
        be,
        graph,
        state,
        prec,
        scaling,
        src,
        PtqOptions::default(),
        calib,
        eval,
    );
    let line = match res {
        Ok(m) => format!(
            "{:<18} {:<11} {:<11} {:<11} {:>6.2} {:>6.2} {:>9.5} {:>8.5} {:>8.5} {:>8.2} {:>9.0} {:>4}",
            be.name,
            m.precision_label(),
            m.scaling_label(),
            label,
            m.top1 * 100.0,
            m.top5 * 100.0,
            m.logit_mse,
            m.brier,
            m.ece,
            m.snr_db,
            m.fps_modelled,
            m.fallback_ops
        ),
        Err(e) => format!(
            "{:<18} {:<11} {:<11} {:<11} unsupported: {e}",
            be.name,
            prec.label(),
            scaling.label(),
            label
        ),
    };
    println!("{line}");
    let _ = writeln!(table, "{line}");
}

/// Artifact-free smoke run: the whole fleet on a synthetic seeded checkpoint.
fn smoke() -> Result<()> {
    let sm = synth::resnet_like(16, 16);
    let state = synthetic_state(&sm);
    let task = Task::Cls(ClsSpec { classes: 10, image: 16, outlier_p: 0.002 });
    let eval: Vec<Batch> = (0..2).map(|i| task.batch(32, 0x5EED_0000 + i)).collect();
    let calib: Vec<Tensor> = (0..2).map(|i| task.batch(8, 0xCA11B_00 + i).images).collect();

    let mut table = String::new();
    let _ = writeln!(
        table,
        "=== Deployment matrix (smoke): synthetic resnet-like 3x16x16, whole fleet x precision ==="
    );
    println!("{}", table.trim_end());
    println!("{HEADER_FMT}");
    let _ = writeln!(table, "{HEADER_FMT}");
    for be in all_backends() {
        for prec in requested_precisions(&be) {
            for scaling in requested_scalings(prec) {
                matrix_row(
                    &mut table,
                    &be,
                    &sm.graph,
                    &state,
                    prec,
                    scaling,
                    "synthetic",
                    RangeSource::Calibration,
                    &calib,
                    &eval,
                );
            }
        }
    }

    // paper Table 4/5 shape: static vs dynamic activation scaling at INT8 on
    // a native-dynamic part — dynamic needs no calibration, costs modelled FPS
    let hd = all_backends().into_iter().find(|b| b.name == "hardware_d").unwrap();
    let _ = writeln!(table, "\n=== static vs dynamic activation scaling on hardware_d (INT8) ===");
    println!("\n=== static vs dynamic activation scaling on hardware_d (INT8) ===");
    for scaling in [ActScaling::Static, ActScaling::Dynamic] {
        // dynamic is deployed calibration-free: zero calibration batches
        let cal: &[Tensor] = if scaling == ActScaling::Dynamic { &[] } else { &calib };
        let m = deploy_and_eval_scaled(
            &hd,
            &sm.graph,
            &state,
            Precision::Int8,
            scaling,
            RangeSource::Calibration,
            PtqOptions::default(),
            cal,
            &eval,
        )?;
        let line = format!(
            "{:<8} SNR {:>7.2} dB   logitMSE {:>9.6}   modelled {:>6.0} FPS",
            m.scaling_label(),
            m.snr_db,
            m.logit_mse,
            m.fps_modelled
        );
        println!("{line}");
        let _ = writeln!(table, "{line}");
    }

    // FP-to-low-bit gap at both weight bit-widths on the same part
    let _ = writeln!(table, "\n=== INT8 vs INT4 gap on hardware_d (W8/A8 vs W4/A8) ===");
    println!("\n=== INT8 vs INT4 gap on hardware_d (W8/A8 vs W4/A8) ===");
    for prec in [Precision::Int8, Precision::Int4] {
        let m = deploy_and_eval(
            &hd,
            &sm.graph,
            &state,
            prec,
            RangeSource::Calibration,
            PtqOptions::default(),
            &calib,
            &eval,
        )?;
        let line = format!(
            "{:<6} SNR {:>7.2} dB   logitMSE {:>9.6}   modelled {:>6.0} FPS",
            m.precision.label(),
            m.snr_db,
            m.logit_mse,
            m.fps_modelled
        );
        println!("{line}");
        let _ = writeln!(table, "{line}");
    }

    std::fs::write("DEPLOY_MATRIX.txt", &table)?;
    println!("\nwrote DEPLOY_MATRIX.txt");
    Ok(())
}

fn main() -> Result<()> {
    if flag("--smoke") {
        return smoke();
    }
    let model = arg("--model", "resnet18");
    let epochs: usize = arg("--epochs", "12").parse()?;
    let dir = artifacts_dir()?;
    let rt = Runtime::cpu()?;
    let classes = if model.ends_with("c10") { 10 } else { 100 };
    let task = Task::Cls(ClsSpec { classes, image: 32, outlier_p: 0.002 });

    // obtain QT + MAP checkpoints (reuse train_cifar outputs when available)
    let load_or_train = |qt: bool| -> Result<TrainState> {
        let suffix = if qt { "qt" } else { "map" };
        let path = dir.join(format!("{model}.trained_{suffix}.qtckpt"));
        if path.exists() {
            println!("using cached checkpoint {}", path.display());
            return Ok(TrainState::from_checkpoint(&Checkpoint::load(path)?));
        }
        let cur = Curriculum::cifar().scaled_to(epochs, 100);
        let cfg = if qt {
            TrainConfig::quant_trim(epochs, 16, cur)
        } else {
            TrainConfig::map_baseline(epochs, 16, cur)
        };
        println!("training {} checkpoint ({epochs} epochs)...", if qt { "QT" } else { "MAP" });
        let (tr, _) = train_with_validation(&rt, &dir, &model, cfg, task, 0, false)?;
        tr.state.to_checkpoint().save(&path)?;
        Ok(tr.state)
    };
    let qt_state = load_or_train(true)?;
    let map_state = load_or_train(false)?;

    let graph = Graph::load(dir.join(format!("{model}.qir")))?;
    let eval: Vec<Batch> = (0..8).map(|i| task.batch(64, 0x5EED_0000 + i)).collect();
    let calib: Vec<Tensor> = (0..4).map(|i| task.batch(16, 0xCA11B_00 + i).images).collect();

    let mut table = String::new();
    println!(
        "\n=== Deployment matrix: {} — every backend x precision (incl. INT4) x method ===",
        model
    );
    println!("{HEADER_FMT}");
    let _ = writeln!(table, "{HEADER_FMT}");
    for be in all_backends() {
        for prec in requested_precisions(&be) {
            for scaling in requested_scalings(prec) {
                for (label, state, src) in [
                    ("Quant-Trim", &qt_state, RangeSource::QatScales),
                    ("MAP", &map_state, RangeSource::Calibration),
                ] {
                    matrix_row(
                        &mut table, &be, &graph, state, prec, scaling, label, src, &calib, &eval,
                    );
                }
            }
        }
    }
    std::fs::write("DEPLOY_MATRIX.txt", &table)?;
    println!("wrote DEPLOY_MATRIX.txt");

    // === Table 3: SNR on Hardware A ===
    // Quant-Trim, calibration only  vs  MAP + Equalization + AdaRound
    println!("\n=== Table 3 analogue: output-layer SNR on hardware_a (A8W8) ===");
    let ha = all_backends().into_iter().find(|b| b.name == "hardware_a").unwrap();
    let qt = deploy_and_eval(
        &ha,
        &graph,
        &qt_state,
        Precision::Int8,
        RangeSource::Calibration, // calibration ONLY — no QAT scales, no extras
        PtqOptions::default(),
        &calib,
        &eval,
    )?;
    let map_eq_ada = deploy_and_eval(
        &ha,
        &graph,
        &map_state,
        Precision::Int8,
        RangeSource::Calibration,
        PtqOptions { equalization: true, adaround: true },
        &calib,
        &eval,
    )?;
    println!("{:<42} {:>8}", "method", "SNR (dB)");
    println!("{:<42} {:>8.2}", "Quant-Trim (calibration only)", qt.snr_db);
    println!("{:<42} {:>8.2}", "MAP baseline (Equalization + AdaRound)", map_eq_ada.snr_db);
    println!(
        "\npaper shape: QT calib-only ({:.1} dB) > MAP+EQ+AdaRound ({:.1} dB): {}",
        qt.snr_db,
        map_eq_ada.snr_db,
        if qt.snr_db > map_eq_ada.snr_db { "REPRODUCED" } else { "NOT reproduced" }
    );
    Ok(())
}
