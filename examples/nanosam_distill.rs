//! NanoSAM2 distillation (paper §5.2, Figs 6-7): distill the student FPN
//! encoder from the frozen teacher under the Quant-Trim curriculum, report
//! feature alignment (Fig 6 quantitative proxy: per-scale feature MSE +
//! saturated-patch rate before/after reverse pruning), then the tiled
//! end-to-end latency story (Fig 7).
//!
//!   cargo run --release --example nanosam_distill -- [--quick]

use anyhow::Result;

use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::artifacts_dir;
use quant_trim::coordinator::{Curriculum, TrainConfig, TrainState, Trainer};
use quant_trim::data::{gen_seg_batch, SegSpec};
use quant_trim::perfmodel::tiles_for;
use quant_trim::runtime::{Manifest, Runtime};

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (epochs, steps) = if quick { (6, 6) } else { (15, 12) };
    let dir = artifacts_dir()?;
    let rt = Runtime::cpu()?;

    let man = Manifest::load(dir.join("sam_student.manifest"))?;
    let teacher_ck = Checkpoint::load(man.file_path("teacher_ckpt")?)?;
    let teacher = TrainState::from_checkpoint(&teacher_ck);

    let cur = Curriculum::seg().scaled_to(epochs, 100);
    let cfg = TrainConfig { base_lr: 5e-4, ..TrainConfig::quant_trim(epochs, steps, cur) };
    let mut tr = Trainer::new(&rt, man, cfg)?;

    let spec = SegSpec::coco_like();
    println!("=== NanoSAM2 distillation: {epochs} epochs x {steps} steps (Huber 3-scale) ===");
    let mut last_mse = f64::NAN;
    for e in 0..epochs {
        let lam = cur.lam(e) as f32;
        if cur.prune_now(e) {
            tr.reverse_prune("reverse_prune_95")?;
        }
        let mut ep_loss = 0.0;
        let mut ep_mse = 0.0;
        for s in 0..steps {
            let b = gen_seg_batch(spec, 8, 0xD15 + (e * steps + s) as u64);
            let (l, m) = tr.distill_step(&teacher, &b.images, lam, 5e-4)?;
            ep_loss += l as f64;
            ep_mse += m as f64;
        }
        last_mse = ep_mse / steps as f64;
        println!(
            "epoch {:>2}  lam {:.3}  huber {:.4}  deep-scale feature MSE {:.5}{}",
            e,
            lam,
            ep_loss / steps as f64,
            last_mse,
            if cur.prune_now(e) { "  [pruned]" } else { "" }
        );
    }

    // Fig 6 proxy: saturated-patch rate of student features (reverse pruning
    // should suppress rare saturated responses)
    let b = gen_seg_batch(spec, 8, 0xF16_6);
    let spec_fwd = tr.fns.manifest().fns["forward"].clone();
    let extras = quant_trim::coordinator::CallExtras {
        data: Some(&b.images),
        ..Default::default()
    };
    let args = tr.state.marshal(&spec_fwd, &extras)?;
    let outs = tr.fns.get("forward")?.call(&args)?;
    println!("\n=== Fig 6 proxy: student FPN feature statistics ===");
    for (i, (slot, lit)) in spec_fwd.rets.iter().zip(outs.iter()).enumerate() {
        let t = quant_trim::runtime::literal_to_tensor(lit, &slot.shape)?;
        let d = quant_trim::metrics::dist_summary(&t.data);
        let sat = t.data.iter().filter(|v| v.abs() > 3.0 * d.p99.max(1e-6)).count() as f64
            / t.data.len() as f64;
        println!(
            "scale {i}: p99 {:.4}  max {:.4}  tail-ratio {:.2}  saturated-frac {:.5}",
            d.p99, d.max, d.tail_ratio, sat
        );
    }
    println!("final deepest-scale teacher/student feature MSE: {last_mse:.5}");

    // Fig 7 / Table 10: tiled inference plan
    let graph = quant_trim::coordinator::experiment::perf_graph(&dir, "sam")?;
    let tiles = tiles_for(2000, 512, 0.5);
    println!("\n=== Fig 7: e2e tiled inference (2k x 2k, {tiles} tiles of 512^2) ===");
    for name in ["hardware_a", "hardware_b", "hardware_d", "jetson_orin_nano", "rtx3090"] {
        let be = quant_trim::backends::backend_by_name(name).unwrap();
        let prec = be.default_precision();
        let r = be.perf(&graph, prec, 1);
        println!(
            "{:<18} {:<5} single-tile {:>8.3} ms  full image {:>7.3} s  @ {:>5.1} W",
            name,
            prec.label(),
            r.latency_ms,
            r.latency_ms * tiles as f64 / 1e3,
            r.peak_power_w
        );
    }
    tr.state.to_checkpoint().save(dir.join("sam_student.trained_qt.qtckpt"))?;
    println!("\nsaved sam_student.trained_qt.qtckpt");
    Ok(())
}
