//! Quickstart: the whole Quant-Trim story in under a minute.
//!
//! 1. load the AOT artifacts (HLO train step, QIR graph, init checkpoint)
//! 2. run a short Quant-Trim curriculum from the Rust coordinator
//! 3. deploy the checkpoint on two very different simulated NPU toolchains
//! 4. print the FP32-vs-INT8 gap both ways
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use quant_trim::backends::{backend_by_name, PtqOptions, RangeSource};
use quant_trim::coordinator::experiment::{
    artifacts_dir, deploy_and_eval, train_with_validation, Task,
};
use quant_trim::coordinator::{Curriculum, TrainConfig};
use quant_trim::data::ClsSpec;
use quant_trim::perfmodel::Precision;
use quant_trim::runtime::Runtime;

fn main() -> Result<()> {
    let dir = artifacts_dir()?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // short curriculum: 8 epochs x 10 steps on synthetic CIFAR-10
    let cur = Curriculum::cifar().scaled_to(8, 100);
    let cfg = TrainConfig::quant_trim(8, 10, cur);
    let task = Task::Cls(ClsSpec::cifar10());
    println!("training resnet18_c10 with Quant-Trim (8 epochs x 10 steps)...");
    let (tr, logs) = train_with_validation(&rt, &dir, "resnet18_c10", cfg, task, 2, true)?;
    let final_acc = logs.last().and_then(|l| l.val_metric).unwrap_or(0.0);
    println!("final val accuracy: {:.3}", final_acc);

    // deploy on two backends with opposite philosophies:
    //   hardware_a: strict INT8, per-tensor weights, DSP rounding, percentile calib
    //   hardware_d: INT8 per-channel, compiler MSE scaling, no calib needed
    let graph = quant_trim::qir::Graph::load(dir.join("resnet18_c10.qir"))?;
    let eval: Vec<_> = (0..4).map(|i| task.batch(64, 0xE0A1 + i)).collect();
    let calib: Vec<_> = (0..4).map(|i| task.batch(16, 0xCA11B + i).images).collect();

    println!("\n{:<12} {:>6} {:>9} {:>8} {:>10}", "backend", "Top-1", "logitMSE", "SNR dB", "est. FPS");
    for name in ["hardware_a", "hardware_d"] {
        let be = backend_by_name(name).unwrap();
        let m = deploy_and_eval(
            &be,
            &graph,
            &tr.state,
            Precision::Int8,
            RangeSource::QatScales,
            PtqOptions::default(),
            &calib,
            &eval,
        )?;
        println!(
            "{:<12} {:>6.2} {:>9.5} {:>8.2} {:>10.0}",
            m.backend,
            m.top1 * 100.0,
            m.logit_mse,
            m.snr_db,
            m.fps_modelled
        );
    }
    println!("\nsame checkpoint, two opaque toolchains, stable INT8 accuracy — that's Quant-Trim.");
    Ok(())
}
