//! Ablation study (paper Appendix B, Table 9 + Figs 8-9): ResNet-18 on
//! synthetic CIFAR-10, five configurations x N seeds:
//!
//!   (1) FP32 baseline         (2) QAT only          (3) reverse pruning only
//!   (4) QAT + 90% clipping    (5) QAT + 99% clipping
//!
//! Expected shape: all configs converge to similar validation accuracy
//! (Fig 8), while weight distributions tighten with clipping aggressiveness
//! (Fig 9) and the QAT+95-style configs yield the lowest deployment MSE.
//!
//!   cargo run --release --example ablation -- [--quick] [--weights]

use anyhow::Result;

use quant_trim::backends::backend_by_name;
use quant_trim::backends::{PtqOptions, RangeSource};
use quant_trim::coordinator::experiment::{
    artifacts_dir, deploy_and_eval, train_with_validation, Task,
};
use quant_trim::coordinator::{Curriculum, TrainConfig};
use quant_trim::data::ClsSpec;
use quant_trim::metrics::dist_summary;
use quant_trim::perfmodel::Precision;
use quant_trim::runtime::Runtime;

struct Config {
    name: &'static str,
    quant_trim: bool,
    prune_fn: Option<&'static str>,
}

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let dump_weights = std::env::args().any(|a| a == "--weights");
    let (epochs, steps, seeds) = if quick { (8, 10, 1) } else { (16, 16, 3) };
    let dir = artifacts_dir()?;
    let rt = Runtime::cpu()?;
    let task = Task::Cls(ClsSpec::cifar10());

    // Table 9 configurations
    let configs = [
        Config { name: "(1) FP32 baseline", quant_trim: false, prune_fn: None },
        Config { name: "(2) QAT only", quant_trim: true, prune_fn: None },
        Config { name: "(3) RP only (95%)", quant_trim: false, prune_fn: Some("reverse_prune_95") },
        Config { name: "(4) QAT + 90% clip", quant_trim: true, prune_fn: Some("reverse_prune_90") },
        Config { name: "(5) QAT + 99% clip", quant_trim: true, prune_fn: Some("reverse_prune_99") },
    ];

    println!("=== Ablation (Table 9): resnet18_c10, {epochs} epochs x {steps} steps, {seeds} seed(s) ===");
    let mut rows = Vec::new();
    for cfg in &configs {
        let mut accs = Vec::new();
        let mut curves: Vec<Vec<f64>> = Vec::new();
        let mut final_state = None;
        for seed in 0..seeds {
            let cur = Curriculum::cifar().scaled_to(epochs, 100);
            let tc = TrainConfig {
                quant_trim: cfg.quant_trim,
                reverse_prune_fn: cfg.prune_fn.map(|s| s.to_string()),
                seed: 0xAB1A + seed as u64 * 7717,
                ..TrainConfig::quant_trim(epochs, steps, cur)
            };
            let (tr, logs) =
                train_with_validation(&rt, &dir, "resnet18_c10", tc, task, 2, false)?;
            accs.push(logs.last().and_then(|l| l.val_metric).unwrap_or(0.0));
            curves.push(logs.iter().map(|l| l.val_metric.unwrap_or(f64::NAN)).collect());
            final_state = Some(tr.state);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        let sd = (accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>()
            / accs.len() as f64)
            .sqrt();
        println!("{:<22} val acc {:.3} ± {:.3}", cfg.name, mean, sd);
        // Fig 8 series (seed 0 curve)
        print!("[fig8] {:<22}", cfg.name);
        for v in &curves[0] {
            print!(" {v:.3}");
        }
        println!();
        rows.push((cfg, mean, final_state.unwrap()));
    }

    // Fig 8 claim: all configurations converge to similar accuracy
    let accs: Vec<f64> = rows.iter().map(|r| r.1).collect();
    let amax = accs.iter().cloned().fold(f64::MIN, f64::max);
    let amin = accs.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "\nFig 8 shape: max-min val acc spread = {:.3} ({})",
        amax - amin,
        if amax - amin < 0.15 { "similar convergence REPRODUCED" } else { "spread too large" }
    );

    // Fig 9: weight distribution comparison across configs
    println!("\n=== Fig 9 analogue: weight distribution per config ===");
    println!(
        "{:<22} {:>9} {:>9} {:>9} {:>11} {:>9}",
        "config", "|w| p99", "|w| p99.9", "|w| max", "tail ratio", "kurtosis"
    );
    for (cfg, _, state) in &rows {
        let mut all: Vec<f32> = Vec::new();
        for (k, t) in &state.params {
            if k.ends_with(".w") {
                all.extend_from_slice(&t.data);
            }
        }
        let d = dist_summary(&all);
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>9.4} {:>11.2} {:>9.2}",
            cfg.name, d.p99, d.p999, d.max, d.tail_ratio, d.kurtosis
        );
    }
    if dump_weights {
        println!("(per-layer summaries)");
        for (cfg, _, state) in &rows {
            for (k, t) in state.params.iter().filter(|(k, _)| k.ends_with(".w")).take(4) {
                let d = dist_summary(&t.data);
                println!("  {} {k}: p99={:.4} max={:.4}", cfg.name, d.p99, d.max);
            }
        }
    }

    // deployment MSE per config on hardware_b (Fig 9 caption: 95% sweet spot)
    println!("\n=== deployment logit-MSE per config (hardware_b INT8) ===");
    let be = backend_by_name("hardware_b").unwrap();
    let graph = quant_trim::qir::Graph::load(dir.join("resnet18_c10.qir"))?;
    let eval: Vec<_> = (0..4).map(|i| task.batch(64, 0xE0A1 + i)).collect();
    let calib: Vec<_> = (0..4).map(|i| task.batch(16, 0xCA11B + i).images).collect();
    for (cfg, _, state) in &rows {
        let m = deploy_and_eval(
            &be,
            &graph,
            state,
            Precision::Int8,
            RangeSource::Calibration,
            PtqOptions::default(),
            &calib,
            &eval,
        )?;
        println!("{:<22} logitMSE {:.5}  top1 {:.2}", cfg.name, m.logit_mse, m.top1 * 100.0);
    }
    Ok(())
}
