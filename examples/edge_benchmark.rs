//! Edge efficiency benchmark (paper Figs 3, 11 + Table 10): power-throughput
//! trade-off across the device fleet for every model, precision, and runtime
//! (vendor-compiled vs naive dispatch), plus the NanoSAM2 tiled-inference
//! cost table with price-per-watt.
//!
//! Latency/power are from the roofline model (DESIGN.md §2) — the *shape*
//! (who wins, by what factor) is the reproduction target, not absolute
//! numbers. Protocol mirrors the paper: batch=1, 20 warmup + 200 timed
//! iterations for the engine-timed rows.
//!
//!   cargo run --release --example edge_benchmark -- [--models resnet18,vit,...]

use anyhow::Result;

use quant_trim::backends::all_backends;
use quant_trim::coordinator::experiment::artifacts_dir;
use quant_trim::perfmodel::{tiles_for, Precision};


fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    let dir = artifacts_dir()?;
    let models = arg("--models", "resnet50,vit,mobilenetv3,unet");

    // === Fig 3 / Fig 11: FPS vs peak power, per device x precision x runtime
    for model in models.split(',') {
        let graph = quant_trim::coordinator::experiment::perf_graph(&dir, model)?;
        println!(
            "\n=== Fig 3/11 analogue: {model} (batch=1, {} MMACs/inf) ===",
            graph.total_macs() / 1_000_000
        );
        println!(
            "{:<18} {:<5} {:<8} {:>9} {:>9} {:>9} {:>11} {:>4}",
            "device", "prec", "runtime", "FPS", "peak W", "avg W", "mJ/inf", "fb"
        );
        for be in all_backends() {
            for prec in be.precisions.clone() {
                // vendor-compiled runtime (filled markers in Fig 3)
                let r = be.perf(&graph, prec, 1);
                println!(
                    "{:<18} {:<5} {:<8} {:>9.1} {:>9.2} {:>9.2} {:>11.3} {:>4}",
                    be.name,
                    prec.label(),
                    "vendor",
                    r.fps,
                    r.peak_power_w,
                    r.avg_power_w,
                    r.energy_mj_per_inf,
                    r.fallback_ops
                );
                // naive dispatch (unfilled markers) — NVIDIA parts only
                if be.runtime_boost > 1.0 {
                    let n = be.perf_naive(&graph, prec, 1);
                    println!(
                        "{:<18} {:<5} {:<8} {:>9.1} {:>9.2} {:>9.2} {:>11.3} {:>4}",
                        be.name,
                        prec.label(),
                        "naive",
                        n.fps,
                        n.peak_power_w,
                        n.avg_power_w,
                        n.energy_mj_per_inf,
                        n.fallback_ops
                    );
                }
            }
        }
    }

    // === Table 10: NanoSAM2 backbone, 2k x 2k tiled inference ===
    let sam = quant_trim::coordinator::experiment::perf_graph(&dir, "sam")?;
    let tiles = tiles_for(2000, 512, 0.5);
    println!("\n=== Table 10 analogue: NanoSAM2 backbone, 2kx2k image ({tiles} tiles) ===");
    println!(
        "{:<18} {:<10} {:>8} {:>10} {:>12} {:>14}",
        "hardware", "runtime", "peak W", "runtime s", "price EUR", "price/W EUR"
    );
    // paper Table 10 rows: device + the precision its runtime used
    let rows: &[(&str, Precision)] = &[
        ("rtx3090", Precision::Fp16),
        ("jetson_orin_nano", Precision::Fp16),
        ("hardware_a", Precision::Int8),
        ("hardware_b", Precision::Bf16),
        ("hardware_c", Precision::Int8),
        ("hardware_d", Precision::Int8),
    ];
    for (name, prec) in rows {
        let be = all_backends().into_iter().find(|b| b.name == *name).unwrap();
        let r = be.perf(&sam, *prec, 1);
        let total_s = r.latency_ms / 1e3 * tiles as f64;
        println!(
            "{:<18} {:<10} {:>8.1} {:>10.3} {:>12.0} {:>14.4}",
            be.name,
            prec.label(),
            r.peak_power_w,
            total_s,
            be.device.price_eur,
            be.device.price_eur / be.device.peak_w / 1000.0
        );
    }

    // Fig 7 analogue: end-to-end single 512x512 tile latency ordering
    println!("\n=== Fig 7 analogue: NanoSAM2 512x512 single-tile latency ===");
    let mut rows7: Vec<(String, f64, f64)> = Vec::new();
    for (name, prec) in rows {
        let be = all_backends().into_iter().find(|b| b.name == *name).unwrap();
        let r = be.perf(&sam, *prec, 1);
        rows7.push((format!("{} ({})", be.name, prec.label()), r.latency_ms, r.peak_power_w));
    }
    rows7.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (name, lat, w) in &rows7 {
        println!("{:<26} {:>8.3} ms @ {:>5.1} W", name, lat, w);
    }
    let ha = rows7.iter().find(|r| r.0.starts_with("hardware_a")).unwrap();
    let jetson = rows7.iter().find(|r| r.0.starts_with("jetson")).unwrap();
    println!(
        "\npaper shape: Hardware A (A8W8, ~5W) ~{:.1}x faster than Jetson FP16: {}",
        jetson.1 / ha.1,
        if ha.1 < jetson.1 { "REPRODUCED" } else { "NOT reproduced" }
    );
    Ok(())
}
