//! Serving demo: the batching inference server routing requests to a
//! simulated NPU deployment (Rust integer engine on the request path —
//! no Python, no JAX). Reports measured latency percentiles, batch sizes,
//! and throughput under open-loop load.
//!
//!   cargo run --release --example serve -- [--requests 256] [--backend hardware_d]

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use quant_trim::backends::{backend_by_name, CheckpointView, PtqOptions, RangeSource};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::artifacts_dir;
use quant_trim::coordinator::server::{serve, BatchPolicy, EngineModel, Request};
use quant_trim::coordinator::TrainState;
use quant_trim::data::{gen_cls_batch, ClsSpec};
use quant_trim::perfmodel::Precision;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    let n_requests: usize = arg("--requests", "256").parse()?;
    let backend = arg("--backend", "hardware_d");
    let dir = artifacts_dir()?;

    // deploy a checkpoint on the chosen backend (trained if available)
    let ck_path = ["resnet18.trained_qt.qtckpt", "resnet18.init.qtckpt"]
        .iter()
        .map(|f| dir.join(f))
        .find(|p| p.exists())
        .unwrap();
    println!("deploying {} on {backend} (INT8)...", ck_path.display());
    let state = TrainState::from_checkpoint(&Checkpoint::load(&ck_path)?);
    let graph = quant_trim::qir::Graph::load(dir.join("resnet18.qir"))?;
    let be = backend_by_name(&backend).expect("unknown backend");
    let task = ClsSpec::cifar100();
    let calib: Vec<_> = (0..4).map(|i| gen_cls_batch(task, 16, 0xCA11B + i).images).collect();
    let view = CheckpointView {
        graph: &graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    let dep = be.compile(view, Precision::Int8, RangeSource::QatScales, &calib, PtqOptions::default())?;
    println!(
        "modelled on-device: {:.0} FPS @ {:.1} W ({} host-fallback ops)",
        dep.perf_b1.fps, dep.perf_b1.peak_power_w, dep.perf_b1.fallback_ops
    );

    // spin up the router + worker
    let model = EngineModel { model: Arc::new(Mutex::new(dep.model)), batch: 16 };
    let policy = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(4) };
    let (tx, handle) = serve(Box::new(model), policy);

    // open-loop load: Poisson-ish arrivals
    println!("sending {n_requests} requests...");
    let data = gen_cls_batch(task, n_requests.min(256), 0x5E64E);
    let sz = 3 * 32 * 32;
    let mut replies = Vec::new();
    let mut rng = quant_trim::testutil::Rng::new(0x10AD);
    for i in 0..n_requests {
        let (rtx, rrx) = mpsc::channel();
        let j = i % data.labels.len();
        let image = quant_trim::tensor::Tensor::new(
            vec![3, 32, 32],
            data.images.data[j * sz..(j + 1) * sz].to_vec(),
        );
        tx.send(Request { image, reply: rtx, submitted: Instant::now() }).unwrap();
        replies.push((data.labels[j], rrx));
        if rng.uniform() < 0.3 {
            std::thread::sleep(Duration::from_micros(rng.below(3000) as u64));
        }
    }
    drop(tx);

    let mut correct = 0usize;
    let mut batch_hist = std::collections::BTreeMap::new();
    for (label, rrx) in replies {
        let resp = rrx.recv()?;
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == label as usize {
            correct += 1;
        }
        *batch_hist.entry(resp.batch_size).or_insert(0usize) += 1;
    }
    let stats = handle.join().unwrap();
    println!("\n=== serving stats (request path: Rust int8 engine only) ===");
    println!("served          {}", stats.served);
    println!("batches         {} (mean batch {:.2})", stats.batches, stats.mean_batch);
    println!("latency p50/p95 {:.2} / {:.2} ms", stats.p50_ms, stats.p95_ms);
    println!("throughput      {:.1} req/s", stats.throughput_rps);
    println!("on-device top-1 {:.2}%", correct as f64 / n_requests as f64 * 100.0);
    println!("batch-size histogram: {batch_hist:?}");
    Ok(())
}
