//! Serving demo: the concurrent batching server routing requests to
//! simulated NPU deployments (Rust integer engine on the request path —
//! no Python, no JAX). Reports measured latency percentiles, batch sizes,
//! throughput, and error/backpressure counts under open-loop load.
//!
//! Single-deployment:
//!   cargo run --release --example serve -- [--requests 256] [--backend hardware_d] [--workers 2]
//! Whole fleet (one server fronting every backend at its default precision,
//! plus `*_int4` deployments where sub-byte kernels exist and
//! calibration-free `*_dyn` dynamic-scaling deployments where the runtime
//! supports live-batch ranges; traffic round-robined across deployments):
//!   cargo run --release --example serve -- --fleet [--workers 4]
//! Sharded cluster (consistent-hash router + N loopback HTTP nodes, each
//! wrapping its own batching server; synthetic checkpoint, no artifacts
//! needed):
//!   cargo run --release --example serve -- --cluster [--nodes 3] [--replication 2] [--requests 96]

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use quant_trim::backends::{
    all_backends, backend_by_name, BackendSpec, CheckpointView, PtqOptions, RangeSource,
};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::artifacts_dir;
use quant_trim::coordinator::server::{
    BatchPolicy, EngineModel, Outcome, Priority, Server, ServerConfig, ServerDeployment,
    SubmitError,
};
use quant_trim::coordinator::TrainState;
use quant_trim::data::{gen_cls_batch, ClsSpec};
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::tensor::Tensor;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

#[allow(clippy::too_many_arguments)]
fn compile_one(
    be: &BackendSpec,
    graph: &quant_trim::qir::Graph,
    state: &TrainState,
    calib: &[Tensor],
    precision: Precision,
    scaling: ActScaling,
    name: &str,
) -> Result<ServerDeployment> {
    let view = CheckpointView {
        graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    let dep =
        be.compile_scaled(view, precision, scaling, RangeSource::QatScales, calib, PtqOptions::default())?;
    println!(
        "  {:<21} @ {:?}/{}: modelled {:.0} FPS @ {:.1} W ({} host-fallback ops)",
        name,
        dep.precision,
        dep.act_scaling.label(),
        dep.perf_b1.fps,
        dep.perf_b1.peak_power_w,
        dep.perf_b1.fallback_ops
    );
    Ok(ServerDeployment {
        name: name.to_string(),
        model: Arc::new(EngineModel::new(Arc::new(dep.model), 16)),
        fallbacks: Vec::new(),
    })
}

/// `--cluster`: a sharded multi-node cluster over loopback HTTP. Compiles a
/// synthetic checkpoint into an INT8 + INT4 serving fleet, shards it across
/// N nodes by consistent hash (R replicas each), and drives keyed traffic
/// through the router's front door — no artifacts needed.
fn run_cluster_demo(n_requests: usize, n_nodes: usize, replication: usize) -> Result<()> {
    use quant_trim::coordinator::cluster::{infer, scrape_metrics, ClusterNode, Router};
    use quant_trim::coordinator::cluster::{NodeConfig, RouterConfig};
    use quant_trim::coordinator::experiment::{compile_serving_fleet, place_fleet_on_nodes};
    use quant_trim::testutil::{synth, Rng};

    println!("compiling synthetic checkpoint for the cluster fleet (hardware_d INT8 + INT4)...");
    let sm = synth::resnet_like(16, 16);
    let mut rng = Rng::new(0xCA11B);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let fleet = compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &[
            ("hardware_d", Some(Precision::Int8), ActScaling::Static),
            ("hardware_d", Some(Precision::Int4), ActScaling::Static),
        ],
        &calib,
        8,
        Some(Duration::from_millis(2)),
    )?;
    let names: Vec<String> = fleet.iter().map(|d| d.name.clone()).collect();

    let node_ids: Vec<String> = (0..n_nodes).map(|i| format!("cluster-n{i}")).collect();
    let shards = place_fleet_on_nodes(&fleet, &node_ids, replication)?;
    let router = Router::start(RouterConfig { replication, ..RouterConfig::default() })?;
    let mut nodes = Vec::new();
    for (id, shard) in node_ids.iter().zip(shards) {
        if shard.is_empty() {
            println!("  {id}: no deployments placed here, not started");
            continue;
        }
        let hosted: Vec<&str> = shard.iter().map(|d| d.name.as_str()).collect();
        println!("  {id}: hosting {hosted:?}");
        nodes.push(ClusterNode::start(
            id.clone(),
            shard,
            NodeConfig::default(),
            Some(router.addr()),
        )?);
    }
    anyhow::ensure!(!nodes.is_empty(), "placement left every node empty");
    let want = nodes.len();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while router.members() < want {
        anyhow::ensure!(std::time::Instant::now() < deadline, "nodes did not register in time");
        std::thread::sleep(Duration::from_millis(10));
    }
    println!(
        "router on {} with {} node(s), replication {replication}, epoch {}\n",
        router.addr(),
        router.members(),
        router.epoch()
    );

    println!("sending {n_requests} keyed requests through the router...");
    let mut by_node: BTreeMap<String, usize> = BTreeMap::new();
    let mut failovers = 0u32;
    let mut served = 0usize;
    for i in 0..n_requests {
        let image = Tensor::new(vec![3, 16, 16], rng.normal_vec(3 * 256, 1.0));
        let reply = infer(
            router.addr(),
            Some(&names[i % names.len()]),
            Some(&format!("req-{i}")),
            &image,
            None,
            Duration::from_secs(30),
        )?;
        anyhow::ensure!(reply.is_served(), "request {i} failed: {:?}", reply.error);
        failovers += reply.failovers;
        served += 1;
        *by_node.entry(reply.node.unwrap_or_default()).or_insert(0) += 1;
    }

    println!("served          {served} (router-level failovers: {failovers})");
    println!("per-node        {by_node:?}");
    let router_metrics = scrape_metrics(router.addr(), Duration::from_secs(5))?;
    println!(
        "router metrics  routed {} forwarded_ok {} no_replica {}",
        router_metrics.get("pallas_router_routed").copied().unwrap_or(0.0),
        router_metrics.get("pallas_router_forwarded_ok").copied().unwrap_or(0.0),
        router_metrics.get("pallas_router_no_replica").copied().unwrap_or(0.0),
    );
    for node in nodes {
        let id = node.id().to_string();
        let stats = node.shutdown();
        println!(
            "  {id}: served {} | p50/p95 {:.2}/{:.2} ms | mean batch {:.2}",
            stats.served, stats.p50_ms, stats.p95_ms, stats.mean_batch
        );
    }
    let rstats = router.shutdown();
    println!("router final    {rstats:?}");
    Ok(())
}

fn main() -> Result<()> {
    let n_requests: usize = arg("--requests", "256").parse()?;
    if flag("--cluster") {
        let n_nodes: usize = arg("--nodes", "3").parse()?;
        let replication: usize = arg("--replication", "2").parse()?;
        return run_cluster_demo(n_requests.min(96), n_nodes, replication);
    }
    // optional per-request SLO deadline in ms (0 = no deadlines)
    let slo_ms: u64 = arg("--slo-ms", "0").parse()?;
    let slo = (slo_ms > 0).then(|| Duration::from_millis(slo_ms));
    let backend = arg("--backend", "hardware_d");
    let workers: usize = arg("--workers", "2").parse()?;
    let fleet_mode = flag("--fleet");
    let dir = artifacts_dir()?;

    // deploy a checkpoint (trained if available)
    let ck_path = ["resnet18.trained_qt.qtckpt", "resnet18.init.qtckpt"]
        .iter()
        .map(|f| dir.join(f))
        .find(|p| p.exists())
        .unwrap();
    println!("deploying {}...", ck_path.display());
    let state = TrainState::from_checkpoint(&Checkpoint::load(&ck_path)?);
    let graph = quant_trim::qir::Graph::load(dir.join("resnet18.qir"))?;
    let task = ClsSpec::cifar100();
    let calib: Vec<_> = (0..4).map(|i| gen_cls_batch(task, 16, 0xCA11B + i).images).collect();

    let mut deployments = Vec::new();
    if fleet_mode {
        // one server fronting every simulated NPU at its default precision,
        // plus W4/A8 deployments of the parts with native int4 kernels and
        // dynamic-scaling deployments of the parts whose runtime can range
        // per batch — the router mixes int4/int8 and static/dynamic traffic
        // in one fleet
        for be in all_backends() {
            let st = ActScaling::Static;
            match compile_one(&be, &graph, &state, &calib, be.default_precision(), st, be.name) {
                Ok(d) => deployments.push(d),
                Err(e) => println!("  {:<21} skipped: {e}", be.name),
            }
            if be.supports_weight_bits(4) {
                let name = format!("{}_int4", be.name);
                match compile_one(&be, &graph, &state, &calib, Precision::Int4, st, &name) {
                    Ok(d) => deployments.push(d),
                    Err(e) => println!("  {:<21} skipped: {e}", name),
                }
            }
            if be.supports_dynamic_act && be.precisions.contains(&Precision::Int8) {
                // calibration-free INT8: live-batch ranges, no calib set
                let name = format!("{}_dyn", be.name);
                match compile_one(&be, &graph, &state, &[], Precision::Int8, ActScaling::Dynamic, &name)
                {
                    Ok(d) => deployments.push(d),
                    Err(e) => println!("  {:<21} skipped: {e}", name),
                }
            }
        }
    } else {
        let be = backend_by_name(&backend).expect("unknown backend");
        deployments
            .push(compile_one(&be, &graph, &state, &calib, Precision::Int8, ActScaling::Static, be.name)?);
    }
    anyhow::ensure!(!deployments.is_empty(), "no deployment compiled");
    let names: Vec<String> = deployments.iter().map(|d| d.name.clone()).collect();

    let server = Server::start(
        deployments,
        ServerConfig {
            workers,
            queue_depth: 512,
            policy: BatchPolicy {
                max_batch: 16,
                max_wait: Duration::from_millis(4),
                slo_margin: slo.map(|_| Duration::from_millis(1)),
            },
            ..ServerConfig::default()
        },
    )?;

    // open-loop load: Poisson-ish arrivals, round-robin across deployments
    println!("sending {n_requests} requests across {} deployment(s)...", names.len());
    let data = gen_cls_batch(task, n_requests.min(256), 0x5E64E);
    let sz = 3 * 32 * 32;
    let mut replies = Vec::new();
    let mut rng = quant_trim::testutil::Rng::new(0x10AD);
    let mut backpressured = 0usize;
    for i in 0..n_requests {
        let j = i % data.labels.len();
        let mut image =
            Tensor::new(vec![3, 32, 32], data.images.data[j * sz..(j + 1) * sz].to_vec());
        let name = &names[i % names.len()];
        loop {
            let deadline = slo.map(|d| std::time::Instant::now() + d);
            match server.submit_image_with(image, Some(name.as_str()), deadline, Priority::Normal)
            {
                Ok(rx) => {
                    replies.push((data.labels[j], rx));
                    break;
                }
                Err(SubmitError::QueueFull(req)) => {
                    // bounded queue: back off and retry instead of buffering
                    backpressured += 1;
                    image = req.image;
                    std::thread::sleep(Duration::from_micros(500));
                }
                Err(SubmitError::Shed(_)) => unreachable!("no shed watermark configured"),
                Err(SubmitError::ShutDown(_)) => anyhow::bail!("server shut down mid-load"),
            }
        }
        if rng.uniform() < 0.3 {
            std::thread::sleep(Duration::from_micros(rng.below(3000) as u64));
        }
    }

    let mut correct = 0usize;
    let mut failed = 0usize;
    let mut batch_hist = BTreeMap::new();
    let mut by_deployment: BTreeMap<String, usize> = BTreeMap::new();
    for (label, rrx) in replies {
        let resp = rrx.recv()?;
        *by_deployment.entry(resp.deployment.clone()).or_insert(0usize) += 1;
        match resp.result {
            Ok(logits) => {
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == label as usize {
                    correct += 1;
                }
                *batch_hist.entry(resp.batch_size).or_insert(0usize) += 1;
            }
            Err(e) => {
                failed += 1;
                if resp.outcome != Outcome::Expired {
                    eprintln!("request failed on {}: {e}", resp.deployment);
                }
            }
        }
    }
    let stats = server.shutdown();
    println!("\n=== serving stats (request path: Rust engine only) ===");
    println!("served          {} ({} error responses)", stats.served, stats.errors);
    println!("batches         {} (mean batch {:.2})", stats.batches, stats.mean_batch);
    println!(
        "latency p50/p95/p99 {:.2} / {:.2} / {:.2} ms",
        stats.p50_ms, stats.p95_ms, stats.p99_ms
    );
    println!("throughput      {:.1} req/s ({workers} workers)", stats.throughput_rps);
    println!("backpressure    {backpressured} retries at submit");
    println!(
        "robustness      shed {} | expired {} | retried {} | degraded {} | breaker trips {}",
        stats.shed, stats.expired, stats.retried, stats.degraded, stats.breaker_trips
    );
    println!(
        "containment     worker panics {} | workers restarted {} | SLO violation rate {:.4}",
        stats.worker_panics,
        stats.workers_restarted,
        stats.slo_violation_rate()
    );
    println!(
        "on-device top-1 {:.2}% ({} failed)",
        correct as f64 / n_requests as f64 * 100.0,
        failed
    );
    println!("per-deployment  {by_deployment:?}");
    println!("batch-size histogram: {batch_hist:?}");
    Ok(())
}
